//! End-to-end serving bench: the full coordinator stack (dynamic
//! batcher → shared work queue → executor pool) under open-loop
//! Poisson traffic, per caching policy. Reports throughput, latency
//! percentiles, *queue wait vs execution time* (the scheduler's own
//! latency contribution, ADR-002), admission rejections, batch
//! occupancy and skip fraction — the serving-system view of the
//! paper's acceleration claim. The per-policy `metrics:` summary line
//! includes the plan-store counters (`plan_hits`/`plan_miss`) so
//! plan-cache behaviour under traffic is visible per run.
//!
//! Flags: `--workers N` sizes the executor replica pool, `--threads N`
//! pins the GEMM compute pool (0 = auto), `--queue-depth N` bounds the
//! shared work queue (rejected requests are counted, not retried),
//! `--deadline-ms N` attaches a best-effort deadline to every request
//! (0 = none) so the `dl miss` column reports how much of the load
//! would have been late under that latency budget, `--smoke` shrinks
//! the run to CI scale (2 steps, a handful of requests), and
//! `--json OUT` writes the machine-readable `BENCH_serving.json`
//! report (docs/benchmarks.md).
//!
//! `--mux N` runs the protocol-v2 multiplexing comparison instead
//! (docs/adr/008): the same request load is driven first serially over
//! one v1 JSON-lines connection (v1's one-in-flight-per-connection
//! ceiling), then as N concurrent streams multiplexed over a single
//! framed v2 socket by `Client2`. The report area is `serving_mux` and
//! the headline row is `mux_speedup_x` — serial v1 wall time over
//! multiplexed v2 wall time, with aggregate and worst-stream p99s.
//!
//! `--mixed-priority` runs the preemptive-scheduling comparison
//! instead of the per-policy sweep: every replica is first saturated
//! with a long generation, then short interactive probes measure the
//! head-of-line latency the long work imposes. Phase A pins the long
//! jobs at `interactive` class (run-to-completion — nothing yields);
//! phase B pins them at `batch` class, so executors park them the
//! moment interactive work arrives (docs/adr/007). The report area is
//! `serving_mixed_w{workers}` and the headline row is
//! `priority:interactive/p99_improvement_x` — the run-to-completion
//! p99 over the preemptive p99.

use std::time::{Duration, Instant};

use smoothcache::coordinator::{
    Coordinator, CoordinatorConfig, Deadline, DeadlinePolicy, Metrics, Policy, PriorityClass,
    Request, SubmitOpts,
};
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{fast_mode, Args, Table};
use smoothcache::workload::PoissonTrace;

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    let workers = args.usize("workers", 2)?;
    let queue_depth = args.usize("queue-depth", 256)?;
    let threads = args.usize("threads", 0)?;
    let deadline_ms = args.usize("deadline-ms", 0)?;
    let smoke = args.flag("smoke")?;
    let mixed = args.flag("mixed-priority")?;
    let mux = args.usize("mux", 0)?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    if threads > 0 {
        smoothcache::tensor::gemm::set_threads(threads);
    }
    std::fs::create_dir_all("bench_out")?;

    if mixed {
        return run_mixed_priority(workers, queue_depth, smoke, json_out.as_deref());
    }
    if mux > 0 {
        return run_mux(workers, queue_depth, mux, smoke, json_out.as_deref());
    }

    let (steps, n_requests, rate_rps) = if smoke {
        (2usize, 6usize, 12.0)
    } else if fast_mode() {
        (8, 16, 8.0)
    } else {
        (50, 48, 4.0)
    };

    let mut report = BenchReport::new("serving");
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("steps", steps);
    report.meta("threads", threads);
    report.meta("workers", workers);
    report.meta("queue_depth", queue_depth);
    report.meta("requests", n_requests);
    report.meta("smoke", smoke);
    report.run_meta(workers);

    let mut table = Table::new(&[
        "policy", "served", "rejected", "dl miss", "throughput (req/s)", "p50 (s)", "p95 (s)",
        "mean qwait (s)", "mean exec (s)", "occupancy", "skip%",
    ]);

    let mut no_cache_throughput = 0.0f64;
    for policy in [
        Policy::no_cache(),
        Policy::fora(2),
        Policy::fora(3),
        Policy::smooth(0.25),
        Policy::smooth(0.5),
        // runtime-adaptive error-feedback policy: no calibration, the
        // StepPlanner decides per (step, site) from observed drift
        Policy::drift(0.35),
    ] {
        let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
        cfg.preload = vec!["image".into()];
        cfg.max_wait = Duration::from_millis(25);
        cfg.calib_samples = if fast_mode() { 2 } else { 6 };
        cfg.workers = workers;
        cfg.queue_depth = queue_depth;
        let coord = Coordinator::start(cfg)?;

        // warmup: force calibration + executable compiles out of the
        // measured window
        let warm = Request {
            id: 0,
            family: "image".into(),
            cond: smoothcache::model::Cond::Label(vec![0]),
            solver: SolverKind::Ddim,
            steps,
            cfg_scale: 1.0,
            seed: 1,
            policy: policy.clone(),
            compute: Default::default(),
            priority: Default::default(),
        };
        coord.generate_blocking(warm.clone())?;
        for b in [2usize, 4] {
            // also compile the larger batch variants
            let rxs: Vec<_> = (0..b)
                .map(|i| {
                    let mut r = warm.clone();
                    r.id = 0;
                    r.seed = 100 + i as u64;
                    coord.submit(r)
                })
                .collect();
            for rx in rxs {
                rx.recv().unwrap()?;
            }
        }

        // measured open-loop run
        let trace = PoissonTrace::generate(rate_rps, n_requests, 10, 0, 0, 0xE2E);
        let t0 = Instant::now();
        let mut pending = Vec::new();
        for (i, item) in trace.items.iter().enumerate() {
            let target = t0 + Duration::from_secs_f64(item.arrival_s);
            if let Some(sleep) = target.checked_duration_since(Instant::now()) {
                std::thread::sleep(sleep);
            }
            let req = Request {
                id: 0,
                family: "image".into(),
                cond: item.cond.clone(),
                solver: SolverKind::Ddim,
                steps,
                cfg_scale: 1.0,
                seed: item.seed ^ i as u64,
                policy: policy.clone(),
                compute: Default::default(),
                priority: Default::default(),
            };
            // optional best-effort deadline: late responses are still
            // delivered and show up in the dl-miss column
            let deadline = (deadline_ms > 0).then(|| {
                Deadline::after(
                    Duration::from_millis(deadline_ms as u64),
                    DeadlinePolicy::BestEffort,
                )
            });
            let opts = SubmitOpts { progress: None, deadline, trace: Default::default() };
            pending.push(coord.submit_opts(req, opts).reply);
        }
        let mut latencies = Vec::new();
        let mut rejected = 0usize;
        let mut skip = 0.0;
        for rx in pending {
            // an overloaded rejection is a valid outcome under a bounded
            // queue — count it instead of aborting the bench; any other
            // error is a real failure and must surface
            match rx.recv().unwrap() {
                Ok(resp) => {
                    latencies.push(resp.total_seconds);
                    skip = resp.gen_stats.skip_fraction();
                }
                Err(e) if format!("{e}").starts_with("overloaded:") => rejected += 1,
                Err(e) => return Err(e),
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let served = latencies.len();
        let pct = |q: f64| {
            if latencies.is_empty() {
                f64::NAN
            } else {
                latencies[((q * (served - 1) as f64) as usize).min(served - 1)]
            }
        };
        let m = coord.metrics();
        let throughput = served as f64 / wall;
        table.row(&[
            policy.wire().to_string(),
            served.to_string(),
            rejected.to_string(),
            Metrics::get(&m.deadline_missed).to_string(),
            format!("{throughput:.2}"),
            format!("{:.3}", pct(0.5)),
            format!("{:.3}", pct(0.95)),
            format!("{:.3}", m.queue_wait.mean()),
            format!("{:.3}", m.exec_latency.mean()),
            format!("{:.2}", m.occupancy()),
            format!("{:.0}%", skip * 100.0),
        ]);
        eprintln!(
            "[e2e] {}: wall={wall:.1}s metrics: {}",
            policy.wire(),
            m.summary()
        );

        // machine-readable per-policy metrics, keyed by the registry
        // wire name so baselines diff cleanly across runs
        let wire = policy.wire().to_string();
        if wire == "no-cache" {
            no_cache_throughput = throughput;
        }
        report.metric_tol(&format!("{wire}/throughput_rps"), throughput, "req/s", true, 80.0)?;
        if served > 0 {
            report.metric_tol(&format!("{wire}/p50_s"), pct(0.5), "s", false, 100.0)?;
            report.metric_tol(&format!("{wire}/p95_s"), pct(0.95), "s", false, 100.0)?;
        }
        report.metric_tol(&format!("{wire}/qwait_mean_s"), m.queue_wait.mean(), "s", false, 150.0)?;
        report.metric_tol(&format!("{wire}/exec_mean_s"), m.exec_latency.mean(), "s", false, 100.0)?;
        report.metric_tol(
            &format!("{wire}/step_mean_ms"),
            m.step_latency.mean() * 1e3,
            "ms",
            false,
            100.0,
        )?;
        let hits = Metrics::get(&m.plan_cache_hits) as f64;
        let misses = Metrics::get(&m.plan_cache_misses) as f64;
        let hit_rate = if hits + misses > 0.0 { hits / (hits + misses) } else { 0.0 };
        report.metric_tol(&format!("{wire}/plan_hit_rate"), hit_rate, "frac", true, 25.0)?;
        report.metric_tol(&format!("{wire}/skip_pct"), skip * 100.0, "%", true, 5.0)?;
        if no_cache_throughput > 0.0 {
            report.metric_tol(
                &format!("{wire}/speedup_vs_no_cache_x"),
                throughput / no_cache_throughput,
                "x",
                true,
                80.0,
            )?;
        }
        report.metric_tol(&format!("{wire}/rejected"), rejected as f64, "req", false, 0.0)?;
        report.metric_tol(
            &format!("{wire}/dl_miss"),
            Metrics::get(&m.deadline_missed) as f64,
            "req",
            false,
            0.0,
        )?;
        coord.shutdown();
    }

    println!(
        "\nE2E serving — image family, DDIM-{steps}, Poisson {rate_rps} req/s, \
         {workers} executor replicas, queue depth {queue_depth}, {} GEMM threads",
        smoothcache::tensor::gemm::threads()
    );
    table.print();
    std::fs::write("bench_out/e2e_serving.csv", table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}

/// Latencies and counters from one mixed-priority phase.
struct PhaseStats {
    /// Sorted client-side e2e latencies of the interactive probes (s).
    probe_latencies: Vec<f64>,
    /// Long jobs that delivered a result (must equal `workers`).
    long_completed: usize,
    /// Executor preemptions observed during the phase.
    preemptions: u64,
    /// Interactive-class e2e p99 as the metrics histogram reports it
    /// (coarser than the client-side measurement: power-of-two buckets).
    hist_p99_s: f64,
}

fn pct_of(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    sorted[((q * (sorted.len() - 1) as f64) as usize).min(sorted.len() - 1)]
}

/// One phase of the mixed-priority comparison: saturate every replica
/// with a long no-cache generation at `long_class`, then run short
/// interactive probes through the contended stack one at a time and
/// time each end to end.
fn run_mixed_phase(
    workers: usize,
    queue_depth: usize,
    long_class: PriorityClass,
    long_steps: usize,
    int_steps: usize,
    n_probes: usize,
) -> smoothcache::util::error::Result<PhaseStats> {
    let mk_req = |steps: usize, priority: PriorityClass, seed: u64| Request {
        id: 0,
        family: "image".into(),
        cond: smoothcache::model::Cond::Label(vec![(seed % 10) as i32]),
        solver: SolverKind::Ddim,
        steps,
        cfg_scale: 1.0,
        seed,
        policy: Policy::no_cache(),
        compute: Default::default(),
        priority,
    };
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(2);
    cfg.workers = workers;
    cfg.queue_depth = queue_depth;
    let coord = Coordinator::start(cfg)?;

    // warm the probe shape so compile/setup cost stays out of the
    // measured window
    coord.generate_blocking(mk_req(int_steps, PriorityClass::Interactive, 1))?;
    let base_steps = Metrics::get(&coord.metrics().steps_executed);

    // one long job per replica; distinct step counts keep their batch
    // keys distinct so the batcher cannot fold them into one batch and
    // leave replicas idle
    let longs: Vec<_> = (0..workers)
        .map(|i| coord.submit(mk_req(long_steps + i, long_class, 1000 + i as u64)))
        .collect();
    let t0 = Instant::now();
    while Metrics::get(&coord.metrics().steps_executed) <= base_steps {
        if t0.elapsed() > Duration::from_secs(600) {
            return Err(smoothcache::err!("mixed-priority: long jobs never started"));
        }
        std::thread::sleep(Duration::from_millis(1));
    }

    // closed-loop interactive probes against the saturated pool
    let mut probe_latencies = Vec::with_capacity(n_probes);
    for i in 0..n_probes {
        let t = Instant::now();
        coord.generate_blocking(mk_req(int_steps, PriorityClass::Interactive, 2000 + i as u64))?;
        probe_latencies.push(t.elapsed().as_secs_f64());
    }

    let mut long_completed = 0usize;
    for rx in longs {
        if rx.recv().map_err(|e| smoothcache::err!("long job reply lost: {e}"))?.is_ok() {
            long_completed += 1;
        }
    }
    let m = coord.metrics();
    let preemptions = Metrics::get(&m.preemptions);
    let hist_p99_s = m.e2e_interactive.quantile(0.99);
    eprintln!(
        "[mixed:{}] metrics: {}",
        match long_class {
            PriorityClass::Interactive => "run-to-completion",
            PriorityClass::Batch => "preemptive",
        },
        m.summary()
    );
    coord.shutdown();
    probe_latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(PhaseStats { probe_latencies, long_completed, preemptions, hist_p99_s })
}

/// The `--mixed-priority` comparison (docs/adr/007): run-to-completion
/// vs preemptive scheduling of long batch-class work under interactive
/// probes, reported as `serving_mixed_w{workers}`.
fn run_mixed_priority(
    workers: usize,
    queue_depth: usize,
    smoke: bool,
    json_out: Option<&str>,
) -> smoothcache::util::error::Result<()> {
    let (long_steps, int_steps, n_probes) = if smoke {
        (64usize, 2usize, 8usize)
    } else if fast_mode() {
        (96, 3, 10)
    } else {
        (256, 6, 16)
    };

    // Phase A: long jobs at interactive class — same class as the
    // probes, so nothing yields and every probe waits for a replica to
    // run its long job to completion.
    let baseline =
        run_mixed_phase(workers, queue_depth, PriorityClass::Interactive, long_steps, int_steps, n_probes)?;
    // Phase B: the same long jobs at batch class — executors park them
    // at the next step boundary whenever a probe is waiting.
    let preemptive =
        run_mixed_phase(workers, queue_depth, PriorityClass::Batch, long_steps, int_steps, n_probes)?;

    let base_p99 = pct_of(&baseline.probe_latencies, 0.99);
    let pre_p99 = pct_of(&preemptive.probe_latencies, 0.99);
    let improvement = if pre_p99 > 0.0 { base_p99 / pre_p99 } else { f64::INFINITY };

    let mut table = Table::new(&[
        "scheduling", "probe p50 (s)", "probe p95 (s)", "probe p99 (s)", "long done", "preempts",
    ]);
    for (name, st) in [("run-to-completion", &baseline), ("preemptive", &preemptive)] {
        table.row(&[
            name.to_string(),
            format!("{:.3}", pct_of(&st.probe_latencies, 0.5)),
            format!("{:.3}", pct_of(&st.probe_latencies, 0.95)),
            format!("{:.3}", pct_of(&st.probe_latencies, 0.99)),
            st.long_completed.to_string(),
            st.preemptions.to_string(),
        ]);
    }
    println!(
        "\nMixed-priority serving — image family, DDIM, {workers} replicas, \
         {n_probes} interactive probes ({int_steps} steps) against {workers} \
         long jobs ({long_steps} steps); interactive p99 improvement {improvement:.1}x"
    );
    table.print();

    let mut report = BenchReport::new(&format!("serving_mixed_w{workers}"));
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("workers", workers);
    report.meta("long_steps", long_steps);
    report.meta("interactive_steps", int_steps);
    report.meta("interactive_probes", n_probes);
    report.meta("smoke", smoke);
    report.run_meta(workers);
    report.metric_tol("priority:interactive/p99_ms", pre_p99 * 1e3, "ms", false, 200.0)?;
    report.metric_tol(
        "priority:interactive/p50_ms",
        pct_of(&preemptive.probe_latencies, 0.5) * 1e3,
        "ms",
        false,
        200.0,
    )?;
    report.metric_tol(
        "priority:interactive/p99_ms_run_to_completion",
        base_p99 * 1e3,
        "ms",
        false,
        200.0,
    )?;
    report.metric_tol("priority:interactive/p99_improvement_x", improvement, "x", true, 80.0)?;
    report.metric_tol(
        "priority:interactive/metrics_p99_s",
        preemptive.hist_p99_s,
        "s",
        false,
        300.0,
    )?;
    // deterministic conservation rows: every long job must finish in
    // both phases (preemption defers work, it never sheds it), and the
    // preemptive phase must actually preempt
    report.metric_tol(
        "priority:batch/completed",
        preemptive.long_completed as f64,
        "req",
        true,
        0.0,
    )?;
    report.metric_tol(
        "priority:batch/completed_run_to_completion",
        baseline.long_completed as f64,
        "req",
        true,
        0.0,
    )?;
    report.metric_tol(
        "priority:batch/preemptions",
        preemptive.preemptions as f64,
        "count",
        true,
        1000.0,
    )?;
    if let Some(path) = json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}

/// The `--mux N` comparison (docs/adr/008): `n_streams × per_stream`
/// identical-shape requests, first serially over one v1 JSON-lines
/// connection, then as `n_streams` concurrent threads multiplexed over
/// a single framed v2 socket. The multiplexed run keeps the window
/// full, so the dynamic batcher folds concurrent streams into larger
/// batches and replicas pipeline — that overlap is `mux_speedup_x`.
fn run_mux(
    workers: usize,
    queue_depth: usize,
    n_streams: usize,
    smoke: bool,
    json_out: Option<&str>,
) -> smoothcache::util::error::Result<()> {
    use smoothcache::server::{Client, Client2, Server};
    use smoothcache::util::json::Json;

    let (steps, per_stream) = if smoke {
        (2usize, 2usize)
    } else if fast_mode() {
        (4, 3)
    } else {
        (8, 4)
    };
    let policy = Policy::fora(2);

    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(10);
    cfg.calib_samples = if fast_mode() { 2 } else { 6 };
    cfg.workers = workers;
    cfg.queue_depth = queue_depth;
    let coord = std::sync::Arc::new(Coordinator::start(cfg)?);

    // warmup out of the measured window: the single shape plus the
    // batch sizes the multiplexed phase can fold concurrent streams
    // into
    let warm = Request {
        id: 0,
        family: "image".into(),
        cond: smoothcache::model::Cond::Label(vec![0]),
        solver: SolverKind::Ddim,
        steps,
        cfg_scale: 1.0,
        seed: 1,
        policy: policy.clone(),
        compute: Default::default(),
        priority: Default::default(),
    };
    coord.generate_blocking(warm.clone())?;
    for b in [2usize, 4, 8] {
        let rxs: Vec<_> = (0..b.min(n_streams.max(2)))
            .map(|i| {
                let mut r = warm.clone();
                r.seed = 100 + i as u64;
                coord.submit(r)
            })
            .collect();
        for rx in rxs {
            rx.recv().unwrap()?;
        }
    }

    let server = Server::start("127.0.0.1:0", std::sync::Arc::clone(&coord), 2)?;
    let req = |stream: usize, i: usize| {
        Json::obj()
            .set("family", "image")
            .set("label", ((stream + i) % 10) as u64)
            .set("solver", "ddim")
            .set("steps", steps)
            .set("policy", policy.wire())
            .set("seed", (7 + stream * per_stream + i) as u64)
    };
    let check = |reply: &Json| -> smoothcache::util::error::Result<()> {
        if reply.get("ok").and_then(|v| v.as_bool()) != Some(true) {
            return Err(smoothcache::err!(
                "mux bench request failed: {}",
                reply.get("error").and_then(|v| v.as_str()).unwrap_or("?")
            ));
        }
        Ok(())
    };

    // Phase A — serial v1: one JSON-lines connection, one in flight at
    // a time (the per-connection ceiling protocol v2 removes)
    let mut v1 = Client::connect(&server.addr)?;
    let t0 = Instant::now();
    let mut serial_lat = Vec::with_capacity(n_streams * per_stream);
    for s in 0..n_streams {
        for i in 0..per_stream {
            let t = Instant::now();
            let reply = v1.call(&req(s, i))?;
            check(&reply)?;
            serial_lat.push(t.elapsed().as_secs_f64());
        }
    }
    let wall_serial = t0.elapsed().as_secs_f64();
    drop(v1); // free the connection-handler slot before phase B

    // Phase B — multiplexed v2: the same load as n_streams concurrent
    // closed-loop streams over ONE framed socket
    let v2 = Client2::connect(&server.addr)?;
    let t0 = Instant::now();
    let stream_lats: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n_streams)
            .map(|s| {
                let v2 = &v2;
                let req = &req;
                let check = &check;
                scope.spawn(move || -> smoothcache::util::error::Result<Vec<f64>> {
                    let mut lats = Vec::with_capacity(per_stream);
                    for i in 0..per_stream {
                        let t = Instant::now();
                        let reply = v2.call(&req(s, i))?;
                        check(&reply)?;
                        lats.push(t.elapsed().as_secs_f64());
                    }
                    Ok(lats)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("mux stream thread panicked"))
            .collect::<smoothcache::util::error::Result<Vec<_>>>()
    })?;
    let wall_mux = t0.elapsed().as_secs_f64();
    drop(v2);
    let summary = {
        let mut c = Client::connect(&server.addr)?;
        c.metrics_summary()?
    };
    server.stop();
    coord.shutdown();

    let served_mux: usize = stream_lats.iter().map(|v| v.len()).sum();
    let mut all: Vec<f64> = stream_lats.iter().flatten().copied().collect();
    all.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let worst_stream_p99 = stream_lats
        .iter()
        .map(|v| {
            let mut v = v.clone();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            pct_of(&v, 0.99)
        })
        .fold(0.0f64, f64::max);
    serial_lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let speedup = if wall_mux > 0.0 { wall_serial / wall_mux } else { f64::INFINITY };

    let mut table = Table::new(&[
        "phase", "conns", "in-flight", "served", "wall (s)", "req/s", "p50 (s)", "p99 (s)",
    ]);
    table.row(&[
        "v1 serial".into(),
        "1".into(),
        "1".into(),
        serial_lat.len().to_string(),
        format!("{wall_serial:.2}"),
        format!("{:.2}", serial_lat.len() as f64 / wall_serial),
        format!("{:.3}", pct_of(&serial_lat, 0.5)),
        format!("{:.3}", pct_of(&serial_lat, 0.99)),
    ]);
    table.row(&[
        "v2 mux".into(),
        "1".into(),
        n_streams.to_string(),
        served_mux.to_string(),
        format!("{wall_mux:.2}"),
        format!("{:.2}", served_mux as f64 / wall_mux),
        format!("{:.3}", pct_of(&all, 0.5)),
        format!("{:.3}", pct_of(&all, 0.99)),
    ]);
    println!(
        "\nProtocol mux — image family, DDIM-{steps}, {} policy, {n_streams} streams × \
         {per_stream} requests, {workers} replicas; mux speedup {speedup:.2}x \
         (target ≥ 1.5x at 2 workers)",
        policy.wire()
    );
    table.print();
    eprintln!("[mux] server metrics: {summary}");

    let mut report = BenchReport::new("serving_mux");
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("steps", steps);
    report.meta("workers", workers);
    report.meta("streams", n_streams);
    report.meta("per_stream", per_stream);
    report.meta("policy", policy.wire());
    report.meta("smoke", smoke);
    report.run_meta(workers);
    report.metric_tol("mux_speedup_x", speedup, "x", true, 60.0)?;
    report.metric_tol("v1_serial_wall_s", wall_serial, "s", false, 150.0)?;
    report.metric_tol("v2_mux_wall_s", wall_mux, "s", false, 150.0)?;
    report.metric_tol("v2_throughput_rps", served_mux as f64 / wall_mux, "req/s", true, 100.0)?;
    report.metric_tol("stream_p99_ms", pct_of(&all, 0.99) * 1e3, "ms", false, 200.0)?;
    report.metric_tol("worst_stream_p99_ms", worst_stream_p99 * 1e3, "ms", false, 200.0)?;
    // conservation: every stream's every request answered exactly once
    report.metric_tol("served", served_mux as f64, "req", true, 0.0)?;
    if let Some(path) = json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
