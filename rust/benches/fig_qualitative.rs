//! Figs. 1/6/7/8 reproduction: qualitative outputs per schedule.
//!
//! * image — PGM renders of channel-0 latents per (schedule, class)
//!   (Fig. 6: No-Cache vs Static vs SmoothCache at two thresholds)
//! * audio — spectrogram-style CSV of |latent| per (schedule, prompt)
//!   (Fig. 7)
//! * video — first/middle/last frame PGMs per schedule (Fig. 8)
//!
//! Everything lands under bench_out/qualitative/.
//!
//! Flags: `--smoke` (CI scale) and `--json OUT` (machine-readable
//! report — for this qualitative bench the gated metric is the output
//! artifact count per modality, docs/benchmarks.md).

use smoothcache::cache::{calibrate, paper_protocol, CachePlan, PlanRef, Schedule};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, GenConfig};
use smoothcache::tensor::Tensor;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{fast_mode, Args};

/// 8-bit PGM render of a [H, W] slice, normalized to the slice range.
fn write_pgm(path: &str, data: &[f32], h: usize, w: usize) -> std::io::Result<()> {
    let lo = data.iter().cloned().fold(f32::MAX, f32::min);
    let hi = data.iter().cloned().fold(f32::MIN, f32::max);
    let span = (hi - lo).max(1e-9);
    let mut out = format!("P2\n{w} {h}\n255\n");
    for y in 0..h {
        for x in 0..w {
            let v = ((data[y * w + x] - lo) / span * 255.0) as u32;
            out.push_str(&format!("{v} "));
        }
        out.push('\n');
    }
    std::fs::write(path, out)
}

fn channel0(latent: &Tensor, h: usize, w: usize, c: usize) -> Vec<f32> {
    // latent [1, H, W, C] → channel 0 plane
    (0..h * w).map(|i| latent.data[i * c]).collect()
}

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    let out_dir = "bench_out/qualitative";
    std::fs::create_dir_all(out_dir)?;
    let mut engine = Engine::open(dir)?;

    let mut report = BenchReport::new("fig_qualitative");
    report.meta("smoke", smoke);
    report.run_meta(0);

    // ---------- image (Fig. 6) ----------
    engine.load_family("image")?;
    let fm = engine.family_manifest("image")?.clone();
    let mut cc = paper_protocol("image");
    if smoke {
        cc.steps = 4;
        cc.num_samples = 1;
    } else if fast_mode() {
        cc.steps = 10;
        cc.num_samples = 2;
    }
    let curves = calibrate(&engine, "image", &cc)?;
    let bts = fm.branch_types.clone();
    let (a_lo, s_lo) = curves.alpha_for_skip_fraction(0.25, &bts);
    let (a_hi, s_hi) = curves.alpha_for_skip_fraction(0.55, &bts);
    let schedules: Vec<(String, Schedule)> = vec![
        ("no-cache".into(), Schedule::no_cache(cc.steps, &bts)),
        ("static-n2".into(), Schedule::fora(cc.steps, &bts, 2)),
        (format!("smooth-lo-a{a_lo:.2}"), s_lo),
        (format!("smooth-hi-a{a_hi:.2}"), s_hi),
    ];
    let sites = fm.branch_sites();
    let mut image_files = 0usize;
    for (name, schedule) in &schedules {
        let plan = CachePlan::from_grouped(schedule, &sites)?;
        for class in [0i32, 3, 7] {
            let cfg = GenConfig::new("image", cc.solver, cc.steps).with_seed(42 + class as u64);
            let out = generate(
                &engine,
                &cfg,
                &Cond::Label(vec![class]),
                PlanRef::Plan(&plan),
                None,
            )?;
            let plane = channel0(&out.latent, 16, 16, 4);
            write_pgm(&format!("{out_dir}/image_{name}_class{class}.pgm"), &plane, 16, 16)?;
            image_files += 1;
        }
        eprintln!("[qualitative] image {name}: done");
    }
    report.metric_tol("image/files_written", image_files as f64, "files", true, 0.0)?;

    // ---------- audio (Fig. 7) ----------
    engine.load_family("audio")?;
    let fma = engine.family_manifest("audio")?.clone();
    let mut cca = paper_protocol("audio");
    if smoke {
        // DPM++(3M) needs solver history, so smoke keeps 6 steps
        cca.steps = 6;
        cca.num_samples = 1;
    } else if fast_mode() {
        cca.steps = 10;
        cca.num_samples = 2;
    }
    let curves_a = calibrate(&engine, "audio", &cca)?;
    let bts_a = fma.branch_types.clone();
    let (aa1, sa1) = curves_a.alpha_for_skip_fraction(0.2, &bts_a);
    let (aa2, sa2) = curves_a.alpha_for_skip_fraction(0.37, &bts_a);
    let schedules_a: Vec<(String, Schedule)> = vec![
        ("no-cache".into(), Schedule::no_cache(cca.steps, &bts_a)),
        (format!("smooth-a{aa1:.2}"), sa1),
        (format!("smooth-a{aa2:.2}"), sa2),
    ];
    let prompt = Cond::Prompt((10..10 + fma.cond_len as i32).collect());
    let sites_a = fma.branch_sites();
    let mut audio_files = 0usize;
    for (name, schedule) in &schedules_a {
        let plan = CachePlan::from_grouped(schedule, &sites_a)?;
        let cfg = GenConfig::new("audio", cca.solver, cca.steps).with_cfg(7.0).with_seed(7);
        let out = generate(&engine, &cfg, &prompt, PlanRef::Plan(&plan), None)?;
        // "spectrogram": |latent| [T, C] as CSV (T rows)
        let mut csv = String::new();
        for t in 0..64 {
            let row: Vec<String> =
                (0..8).map(|c| format!("{:.4}", out.latent.data[t * 8 + c].abs())).collect();
            csv.push_str(&row.join(","));
            csv.push('\n');
        }
        std::fs::write(format!("{out_dir}/audio_{name}_spectrogram.csv"), csv)?;
        audio_files += 1;
        eprintln!("[qualitative] audio {name}: done");
    }
    report.metric_tol("audio/files_written", audio_files as f64, "files", true, 0.0)?;

    // ---------- video (Fig. 8) ----------
    engine.load_family("video")?;
    let fmv = engine.family_manifest("video")?.clone();
    let mut ccv = paper_protocol("video");
    if smoke {
        ccv.steps = 4;
        ccv.num_samples = 1;
    } else if fast_mode() {
        ccv.steps = 8;
        ccv.num_samples = 2;
    }
    let curves_v = calibrate(&engine, "video", &ccv)?;
    let bts_v = fmv.branch_types.clone();
    let (av, sv) = curves_v.alpha_for_skip_fraction(0.2, &bts_v);
    let schedules_v: Vec<(String, Schedule)> = vec![
        ("no-cache".into(), Schedule::no_cache(ccv.steps, &bts_v)),
        (format!("smooth-a{av:.2}"), sv),
    ];
    let vprompt = Cond::Prompt((20..20 + fmv.cond_len as i32).collect());
    let sites_v = fmv.branch_sites();
    let mut video_files = 0usize;
    for (name, schedule) in &schedules_v {
        let plan = CachePlan::from_grouped(schedule, &sites_v)?;
        let cfg = GenConfig::new("video", ccv.solver, ccv.steps).with_cfg(7.0).with_seed(21);
        let out = generate(&engine, &cfg, &vprompt, PlanRef::Plan(&plan), None)?;
        // first / middle / last frame, channel 0
        for (tag, f) in [("first", 0usize), ("middle", 2), ("last", 3)] {
            let frame_len = 8 * 8 * 4;
            let start = f * frame_len;
            let plane: Vec<f32> =
                (0..64).map(|i| out.latent.data[start + i * 4]).collect();
            write_pgm(&format!("{out_dir}/video_{name}_{tag}.pgm"), &plane, 8, 8)?;
            video_files += 1;
        }
        eprintln!("[qualitative] video {name}: done");
    }
    report.metric_tol("video/files_written", video_files as f64, "files", true, 0.0)?;

    println!("qualitative outputs written to {out_dir}/");
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
