//! §Perf microbench: engine hot-path decomposition. Measures per-entry
//! backend execution latency, host-upload overhead, and the full-step /
//! full-generation path at each batch size — the profile that drives
//! the L3 optimization loop in EXPERIMENTS.md §Perf. The final section
//! sweeps the GEMM compute-thread count over the single-request forward
//! and reports the 4-thread / 1-thread throughput ratio (ISSUE 2
//! acceptance: ≥ 2×), a section decomposes coordinator latency into
//! work-queue wait vs execution time under a burst (ISSUE 3 — the
//! shared work-queue scheduler's own overhead), and a scheduling-
//! overhead section compares the dense `CachePlan` decision lookup
//! against the old string-keyed per-site map path (ISSUE 4). Two
//! `compute:*` sections cover the kernel-dispatch work (ISSUE 7): a
//! SIMD-vs-scalar GEMM timing on wide FFN shapes (acceptance: ≥ 4× on
//! AVX2 hosts) and a precision-ladder sweep reporting per-mode forward
//! latency plus the `quality::precision_gate` SSIM of each reduced-
//! precision trajectory against the f32 reference. An `obs:` section
//! (ISSUE 10) measures the tracing seams' cost: the disabled event
//! call must stay at noise level and the always-on coarse default
//! under 3% of the serving burst (docs/adr/009).
//!
//! Flags: `--threads N` pins the pool for the per-entry sections
//! (0 = auto; the sweep section always pins its own counts); `--smoke`
//! shrinks everything to CI scale; `--json OUT` writes the
//! machine-readable `BENCH_engine.json` report (docs/benchmarks.md).

use std::time::Duration;

use smoothcache::cache::{CachePlan, Decision, PlanRef, Schedule};
use smoothcache::coordinator::{Coordinator, CoordinatorConfig, Metrics, Policy, Request};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, GenConfig, GenSession};
use smoothcache::quality::precision_gate;
use smoothcache::solvers::SolverKind;
use smoothcache::tensor::{gemm, quant, ComputeMode, Tensor};
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{bench, fast_mode, Args, Table};
use smoothcache::util::rng::Rng;

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    let cli_threads = args.usize("threads", 0)?;
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    if cli_threads > 0 {
        gemm::set_threads(cli_threads);
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("image")?;
    let fm = engine.family_manifest("image")?.clone();
    let iters = if fast_mode() { 5 } else { 50 };
    let gen_steps = if smoke { 2usize } else { 10 };

    let mut report = BenchReport::new("engine");
    report.meta("family", "image");
    report.meta("solver", "ddim");
    report.meta("steps", gen_steps);
    report.meta("threads", cli_threads);
    report.meta("workers", 2);
    report.meta("smoke", smoke);
    report.run_meta(2);
    report.meta("simd", gemm::active_kernel_name());

    let mut table = Table::new(&["operation", "batch", "mean (us)", "p95 (us)"]);
    let mut rng = Rng::new(1);

    let batches: &[usize] = if smoke { &[1] } else { &[1, 4, 8] };
    for &batch in batches {
        engine.warmup("image", batch)?;
        let x = Tensor::randn(vec![batch, 16, 16, 4], &mut rng);
        let t = vec![0.5f32; batch];
        let cond = Cond::Label(vec![1; batch]);
        let emb = engine.embed("image", &x, &t, &cond)?;
        let ctx = engine.make_step_ctx(&emb)?;
        let tokens = emb.tokens.clone();

        // per-step conditioning staging overhead alone (device upload on
        // PJRT, host clone on the reference backend)
        let up = bench(3, iters, || {
            let _ = engine.make_step_ctx(&emb).unwrap();
        });
        table.row(&[
            "stage step ctx (c/cond)".into(),
            batch.to_string(),
            format!("{:.0}", up.mean_s * 1e6),
            format!("{:.0}", up.p95_s * 1e6),
        ]);

        // per-entry executions
        let e = bench(3, iters, || {
            let _ = engine.embed("image", &x, &t, &cond).unwrap();
        });
        table.row(&[
            "embed".into(),
            batch.to_string(),
            format!("{:.0}", e.mean_s * 1e6),
            format!("{:.0}", e.p95_s * 1e6),
        ]);
        for br in &fm.branch_types {
            let s = bench(3, iters, || {
                let _ = engine.branch("image", 0, br, &tokens, &ctx).unwrap();
            });
            table.row(&[
                format!("branch.{br}"),
                batch.to_string(),
                format!("{:.0}", s.mean_s * 1e6),
                format!("{:.0}", s.p95_s * 1e6),
            ]);
        }
        let f = bench(3, iters, || {
            let _ = engine.final_head("image", &tokens, &ctx).unwrap();
        });
        table.row(&[
            "final".into(),
            batch.to_string(),
            format!("{:.0}", f.mean_s * 1e6),
            format!("{:.0}", f.p95_s * 1e6),
        ]);

        // whole forward (one diffusion step equivalent)
        let fw = bench(1, iters / 2 + 1, || {
            let _ = engine.forward("image", &x, &t, &cond, None).unwrap();
        });
        table.row(&[
            "full forward (1 step)".into(),
            batch.to_string(),
            format!("{:.0}", fw.mean_s * 1e6),
            format!("{:.0}", fw.p95_s * 1e6),
        ]);
        if batch == 1 {
            report.metric_tol("forward_b1_mean_us", fw.mean_s * 1e6, "us", false, 100.0)?;
        }
    }

    // end-to-end generation micro
    for &(steps, skip) in &[(gen_steps, false), (gen_steps, true)] {
        let cond = Cond::Label(vec![1, 2, 3, 4]);
        let sites = fm.branch_sites();
        let plan = if skip {
            let schedule = Schedule::fora(steps, &fm.branch_types, 2);
            CachePlan::from_grouped(&schedule, &sites)?
        } else {
            CachePlan::no_cache(steps, &sites)
        };
        let g = bench(1, (iters / 10).max(2), || {
            let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(3);
            let _ = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
        });
        table.row(&[
            format!("generate {steps}-step b4 {}", if skip { "fora:2" } else { "no-cache" }),
            "4".into(),
            format!("{:.0}", g.mean_s * 1e6),
            format!("{:.0}", g.p95_s * 1e6),
        ]);
        let name = if skip { "generate_fora2_mean_us" } else { "generate_nocache_mean_us" };
        report.metric_tol(name, g.mean_s * 1e6, "us", false, 100.0)?;
    }

    // ---- session-stepping overhead: one-shot driver vs manual steps ----
    // The serving executor drives a GenSession step by step (checking a
    // cancellation flag between steps); this section pins that the
    // step-driven surface costs nothing measurable over the one-shot
    // loop it replaced.
    {
        let sess_steps = gen_steps;
        let sites = fm.branch_sites();
        let schedule = Schedule::fora(sess_steps, &fm.branch_types, 2);
        let plan = CachePlan::from_grouped(&schedule, &sites)?;
        let cond = Cond::Label(vec![1, 2, 3, 4]);
        let cfg = GenConfig::new("image", SolverKind::Ddim, sess_steps).with_seed(3);
        let sess_iters = (iters / 10).max(2);
        let driver = bench(1, sess_iters, || {
            let _ = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
        });
        let cancelled = std::sync::atomic::AtomicBool::new(false);
        let stepped = bench(1, sess_iters, || {
            let mut s =
                GenSession::new(&engine, &cfg, &cond, PlanRef::Plan(&plan)).unwrap();
            while !s.is_done() {
                // the executor's between-step check, modelled exactly
                if cancelled.load(std::sync::atomic::Ordering::SeqCst) {
                    break;
                }
                s.step().unwrap();
            }
            let _ = s.finish();
        });
        let mut sess_table = Table::new(&["path", "mean (us)", "p95 (us)", "overhead"]);
        sess_table.row(&[
            "generate (one-shot driver)".into(),
            format!("{:.0}", driver.mean_s * 1e6),
            format!("{:.0}", driver.p95_s * 1e6),
            "1.00x".into(),
        ]);
        sess_table.row(&[
            "GenSession steps + cancel check".into(),
            format!("{:.0}", stepped.mean_s * 1e6),
            format!("{:.0}", stepped.p95_s * 1e6),
            format!("{:.2}x", stepped.mean_s / driver.mean_s),
        ]);
        println!(
            "\n§Perf — session-stepping overhead ({sess_steps}-step fora:2 generation, batch 4)"
        );
        sess_table.print();
        std::fs::write("bench_out/perf_engine_session.csv", sess_table.to_csv())?;
        report.metric_tol("session_overhead_x", stepped.mean_s / driver.mean_s, "x", false, 60.0)?;
    }

    let stats = engine.stats();
    println!("\n§Perf — engine hot-path decomposition (image family)");
    table.print();
    println!(
        "\ncumulative runtime stats: {} executions ({:.3}s exec, {:.3}s upload over {} uploads, {} compiles {:.2}s)",
        stats.executions, stats.exec_seconds, stats.upload_seconds, stats.uploads,
        stats.compiles, stats.compile_seconds
    );
    std::fs::write("bench_out/perf_engine.csv", table.to_csv())?;

    // ---- scheduling overhead: dense CachePlan vs string-keyed map ----
    // The generate loop used to pay a format!("{block}.{br}") heap
    // allocation plus a BTreeMap lookup per site per step; a CachePlan
    // decision is one flat-array read. Walk a full 50-step plan both
    // ways and report decision-lookup throughput.
    {
        let sched_steps = if smoke { 8usize } else { 50 };
        let sites = fm.branch_sites();
        let schedule = Schedule::fora(sched_steps, &fm.branch_types, 2);
        let plan = CachePlan::from_grouped(&schedule, &sites)?;
        let mut legacy: std::collections::BTreeMap<String, Vec<Decision>> =
            std::collections::BTreeMap::new();
        for (s_idx, (b, t)) in sites.iter().enumerate() {
            legacy.insert(
                format!("{b}.{t}"),
                (0..sched_steps).map(|s| plan.decision(s, s_idx)).collect(),
            );
        }
        let lookups = (sched_steps * sites.len()) as f64;
        let sched_iters = if fast_mode() { 3 } else { 2000 };
        let mut sink = 0usize;
        let dense = bench(10, sched_iters, || {
            let mut computes = 0usize;
            for s in 0..sched_steps {
                for idx in 0..sites.len() {
                    if plan.decision(s, idx).is_compute() {
                        computes += 1;
                    }
                }
            }
            sink = sink.wrapping_add(computes);
        });
        let stringy = bench(10, sched_iters, || {
            let mut computes = 0usize;
            for s in 0..sched_steps {
                for (b, t) in &sites {
                    let d = legacy
                        .get(&format!("{b}.{t}"))
                        .map(|ds| ds[s])
                        .unwrap_or(Decision::Compute);
                    if d.is_compute() {
                        computes += 1;
                    }
                }
            }
            sink = sink.wrapping_add(computes);
        });
        assert!(sink > 0, "decision walks must not be optimised away");
        let mut sched_table =
            Table::new(&["decision path", "ns/lookup", "lookups/sec", "speedup"]);
        let dense_ns = dense.mean_s * 1e9 / lookups;
        let stringy_ns = stringy.mean_s * 1e9 / lookups;
        sched_table.row(&[
            "dense CachePlan (flat array)".into(),
            format!("{dense_ns:.1}"),
            format!("{:.2e}", lookups / dense.mean_s),
            format!("{:.1}x", stringy.mean_s / dense.mean_s),
        ]);
        sched_table.row(&[
            "string-keyed BTreeMap (legacy)".into(),
            format!("{stringy_ns:.1}"),
            format!("{:.2e}", lookups / stringy.mean_s),
            "1.0x".into(),
        ]);
        println!(
            "\n§Perf — scheduling overhead: {sched_steps}-step × {}-site decision walk",
            sites.len()
        );
        sched_table.print();
        std::fs::write("bench_out/perf_engine_sched.csv", sched_table.to_csv())?;
        report.metric_tol(
            "sched_speedup_dense_vs_map_x",
            stringy.mean_s / dense.mean_s,
            "x",
            true,
            80.0,
        )?;
    }

    // ---- wire-envelope parse: full JSON tree vs lazy scan_field ----
    // The v2 request hot path only needs cmd/id/stream out of the
    // envelope before dispatch; util::json::scan_field extracts them
    // in one zero-allocation pass instead of building (and dropping)
    // the whole value tree (docs/adr/008).
    {
        use smoothcache::util::json::{parse as json_parse, scan_bool, scan_str, scan_u64};
        let envelope = r#"{"cmd":"generate","id":90210,"stream":true,"family":"image","label":7,"solver":"ddim","steps":50,"cfg":1.5,"seed":123456789,"policy":"smooth:0.35","compute":"f16","priority":"interactive","deadline_ms":2500,"deadline_policy":"best-effort","prompt_ids":[1,2,3,4,5,6,7,8,9,10,11,12,13,14,15,16]}"#;
        let scan_iters = if fast_mode() { 50 } else { 20000 };
        let mut sink = 0u64;
        let full = bench(10, scan_iters, || {
            let j = json_parse(envelope).unwrap();
            let id = j.get("id").and_then(|v| v.as_u64()).unwrap_or(0);
            let stream = j.get("stream").and_then(|v| v.as_bool()).unwrap_or(false);
            let cmd_len = j.get("cmd").and_then(|v| v.as_str()).map(|s| s.len()).unwrap_or(0);
            sink = sink.wrapping_add(id + stream as u64 + cmd_len as u64);
        });
        let lazy = bench(10, scan_iters, || {
            let id = scan_u64(envelope, "id").unwrap_or(0);
            let stream = scan_bool(envelope, "stream").unwrap_or(false);
            let cmd_len = scan_str(envelope, "cmd").map(|s| s.len()).unwrap_or(0);
            sink = sink.wrapping_add(id + stream as u64 + cmd_len as u64);
        });
        assert!(sink > 0, "envelope extractions must not be optimised away");
        let speedup = full.mean_s / lazy.mean_s;
        let mut scan_table = Table::new(&["envelope parse", "us/envelope", "envelopes/sec", "speedup"]);
        scan_table.row(&[
            "lazy scan_field (cmd+id+stream)".into(),
            format!("{:.2}", lazy.mean_s * 1e6),
            format!("{:.2e}", 1.0 / lazy.mean_s),
            format!("{speedup:.1}x"),
        ]);
        scan_table.row(&[
            "full tree parse".into(),
            format!("{:.2}", full.mean_s * 1e6),
            format!("{:.2e}", 1.0 / full.mean_s),
            "1.0x".into(),
        ]);
        println!(
            "\n§Perf — wire envelope: lazy scan vs full parse ({}-byte request)",
            envelope.len()
        );
        scan_table.print();
        std::fs::write("bench_out/perf_engine_json_scan.csv", scan_table.to_csv())?;
        report.metric_tol("json_scan/speedup_x", speedup, "x", true, 80.0)?;
        report.metric_tol(
            "json_scan/lazy_us_per_envelope",
            lazy.mean_s * 1e6,
            "us",
            false,
            100.0,
        )?;
    }

    // ---- parallel-substrate sweep: single-request forward vs threads ----
    // (results are bitwise thread-count-invariant; only wall time moves)
    let mut sweep = Table::new(&["threads", "fwd mean (us)", "fwd/s", "speedup vs 1t"]);
    let x1 = Tensor::randn(vec![1, 16, 16, 4], &mut rng);
    let t1 = vec![0.5f32; 1];
    let cond1 = Cond::Label(vec![1]);
    let sweep_iters = if fast_mode() { 5 } else { 30 };
    let mut base_mean = 0.0f64;
    let mut mean_at = std::collections::HashMap::new();
    let thread_counts: &[usize] = if smoke { &[1, 4] } else { &[1, 2, 4, 8] };
    for &nt in thread_counts {
        let s = gemm::with_threads(nt, || {
            bench(2, sweep_iters, || {
                let _ = engine.forward("image", &x1, &t1, &cond1, None).unwrap();
            })
        });
        if nt == 1 {
            base_mean = s.mean_s;
        }
        mean_at.insert(nt, s.mean_s);
        sweep.row(&[
            nt.to_string(),
            format!("{:.0}", s.mean_s * 1e6),
            format!("{:.1}", 1.0 / s.mean_s),
            format!("{:.2}x", base_mean / s.mean_s),
        ]);
    }
    println!("\n§Perf — parallel GEMM substrate: single-request image forward");
    sweep.print();
    let ratio4 = base_mean / mean_at.get(&4).copied().unwrap_or(base_mean);
    println!(
        "throughput at 4 threads vs 1 thread: {ratio4:.2}x (acceptance target >= 2x)"
    );
    std::fs::write("bench_out/perf_engine_threads.csv", sweep.to_csv())?;
    report.metric_tol("threads_speedup_4t_v_1t_x", ratio4, "x", true, 60.0)?;

    // ---- kernel dispatch: SIMD vs scalar GEMM on wide FFN shapes ----
    // The vectorised microkernel keeps the scalar per-element
    // accumulation order (bitwise parity, see tests/parallel_parity.rs);
    // this section records how much faster it runs the FFN-shaped
    // matmuls that dominate a forward (ISSUE 7 acceptance: ≥ 4× on
    // AVX2 hosts; `simd` in the report meta names the kernel in play).
    {
        let shapes: &[(usize, usize, usize)] = &[(64, 128, 512), (64, 512, 128)];
        let mats: Vec<(usize, usize, usize, Vec<f32>, Vec<f32>, Vec<f32>)> = shapes
            .iter()
            .map(|&(m, k, n)| {
                (m, k, n, rng.normal_vec(m * k), rng.normal_vec(k * n), rng.normal_vec(n))
            })
            .collect();
        let kern_iters = if fast_mode() { 5 } else { 200 };
        let mut sink = 0.0f64;
        let scalar = gemm::with_kernel(gemm::Kernel::Scalar, || {
            bench(3, kern_iters, || {
                for (m, k, n, x, w, b) in &mats {
                    let y = gemm::matmul(x, *m, *k, w, *n, Some(b));
                    sink += y[0] as f64;
                }
            })
        });
        let auto = gemm::with_kernel(gemm::Kernel::Auto, || {
            bench(3, kern_iters, || {
                for (m, k, n, x, w, b) in &mats {
                    let y = gemm::matmul(x, *m, *k, w, *n, Some(b));
                    sink += y[0] as f64;
                }
            })
        });
        assert!(sink.is_finite(), "GEMM timing loops must not be optimised away");
        let speedup = scalar.mean_s / auto.mean_s;
        let mut ktable = Table::new(&["kernel", "mean (us)", "p95 (us)", "speedup"]);
        ktable.row(&[
            "scalar (parity reference)".into(),
            format!("{:.0}", scalar.mean_s * 1e6),
            format!("{:.0}", scalar.p95_s * 1e6),
            "1.00x".into(),
        ]);
        ktable.row(&[
            format!("auto ({})", gemm::active_kernel_name()),
            format!("{:.0}", auto.mean_s * 1e6),
            format!("{:.0}", auto.p95_s * 1e6),
            format!("{:.2}x", speedup),
        ]);
        println!(
            "\n§Perf — kernel dispatch: wide-FFN GEMM (64x128x512 + 64x512x128), scalar vs auto"
        );
        ktable.print();
        std::fs::write("bench_out/perf_engine_kernels.csv", ktable.to_csv())?;
        report.metric_tol("compute:simd/ffn_speedup_x", speedup, "x", true, 60.0)?;
    }

    // ---- precision ladder: per-mode forward latency + quality gate ----
    // Reduced-precision weight storage (f16 / bf16 / int8, f32
    // accumulation — docs/adr/006) trades exactness for bandwidth; the
    // gate below holds each mode's 3-step trajectory to the SSIM floor
    // tests/compute_modes.rs pins (f16 ≥ 0.99, bf16/int8 ≥ 0.95).
    {
        let floors: &[(ComputeMode, f64)] = &[
            (ComputeMode::F32, 0.0),
            (ComputeMode::F16, 0.99),
            (ComputeMode::Bf16, 0.95),
            (ComputeMode::Int8, 0.95),
        ];
        let sites = fm.branch_sites();
        let plan = CachePlan::no_cache(3, &sites);
        // same trajectory tests/compute_modes.rs pins against the floors
        let cond = Cond::Label(vec![3]);
        let gen_at = |mode: ComputeMode| {
            let cfg = GenConfig::new("image", SolverKind::Ddim, 3)
                .with_seed(11)
                .with_compute(mode);
            generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap().latent
        };
        let reference = gen_at(ComputeMode::F32);
        let mode_iters = if fast_mode() { 5 } else { 30 };
        let mut ptable = Table::new(&["compute", "fwd b1 mean (us)", "ssim vs f32", "gate"]);
        for &(mode, floor) in floors {
            let fw = quant::with_compute(mode, || {
                bench(2, mode_iters, || {
                    let _ = engine.forward("image", &x1, &t1, &cond1, None).unwrap();
                })
            });
            report.metric_tol(
                &format!("compute:{}/forward_b1_mean_us", mode.name()),
                fw.mean_s * 1e6,
                "us",
                false,
                100.0,
            )?;
            let (ssim_str, gate_str) = if mode == ComputeMode::F32 {
                ("1.000000 (identity)".into(), "-".to_string())
            } else {
                let gate = precision_gate(&reference, &gen_at(mode), floor)?;
                assert!(
                    gate.pass,
                    "compute:{} ssim {:.6} below the {floor} quality floor",
                    mode.name(),
                    gate.ssim
                );
                report.metric_tol(
                    &format!("compute:{}/ssim", mode.name()),
                    gate.ssim,
                    "ssim",
                    true,
                    5.0,
                )?;
                (format!("{:.6}", gate.ssim), format!("pass (>= {floor})"))
            };
            ptable.row(&[
                mode.name().into(),
                format!("{:.0}", fw.mean_s * 1e6),
                ssim_str,
                gate_str,
            ]);
        }
        println!(
            "\n§Perf — precision ladder: single-request image forward per compute mode"
        );
        ptable.print();
        std::fs::write("bench_out/perf_engine_compute.csv", ptable.to_csv())?;
    }

    // ---- queue decomposition: scheduler wait vs execution under a burst ----
    // A closed burst of compatible requests through the full coordinator
    // (batcher → shared work queue → executor pool): how much of each
    // request's latency is the scheduler's own queueing vs model time.
    let (burst, qsteps) = if smoke {
        (4usize, 2usize)
    } else if fast_mode() {
        (8, 4)
    } else {
        (24, 10)
    };
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(5);
    cfg.workers = 2;
    let coord = Coordinator::start(cfg)?;
    let rxs: Vec<_> = (0..burst)
        .map(|i| {
            coord.submit(Request {
                id: 0,
                family: "image".into(),
                cond: Cond::Label(vec![(i % 10) as i32]),
                solver: SolverKind::Ddim,
                steps: qsteps,
                cfg_scale: 1.0,
                seed: i as u64,
                policy: Policy::no_cache(),
                compute: Default::default(),
                priority: Default::default(),
            })
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap()?;
    }
    let m = coord.metrics();
    let mut qtable = Table::new(&["stage", "mean (ms)", "p95 (ms)"]);
    qtable.row(&[
        "queue wait (enqueue→pull)".into(),
        format!("{:.2}", m.queue_wait.mean() * 1e3),
        format!("{:.2}", m.queue_wait.quantile(0.95) * 1e3),
    ]);
    qtable.row(&[
        "batch execute".into(),
        format!("{:.2}", m.exec_latency.mean() * 1e3),
        format!("{:.2}", m.exec_latency.quantile(0.95) * 1e3),
    ]);
    qtable.row(&[
        "submit→exec start (incl. batcher)".into(),
        format!("{:.2}", m.queue_latency.mean() * 1e3),
        format!("{:.2}", m.queue_latency.quantile(0.95) * 1e3),
    ]);
    qtable.row(&[
        "end-to-end".into(),
        format!("{:.2}", m.e2e_latency.mean() * 1e3),
        format!("{:.2}", m.e2e_latency.quantile(0.95) * 1e3),
    ]);
    println!(
        "\n§Perf — work-queue scheduler decomposition \
         ({burst}-request no-cache burst, DDIM-{qsteps}, 2 replicas, peak queue depth {})",
        Metrics::get(&m.queue_peak_depth)
    );
    qtable.print();
    std::fs::write("bench_out/perf_engine_queue.csv", qtable.to_csv())?;
    report.metric_tol("queue_wait_mean_ms", m.queue_wait.mean() * 1e3, "ms", false, 150.0)?;
    report.metric_tol("exec_mean_ms", m.exec_latency.mean() * 1e3, "ms", false, 100.0)?;
    report.metric_tol("e2e_mean_ms", m.e2e_latency.mean() * 1e3, "ms", false, 100.0)?;
    coord.shutdown();

    // ---- obs: tracing overhead (disabled vs coarse vs fine) ----
    // The tracing seams (docs/adr/009) ride the serving hot path, so
    // this section pins what they cost: a disabled event call must stay
    // at noise level, and the always-on coarse default must stay under
    // 3% on the serving smoke burst. Fine granularity (per-site events)
    // is reported for reference but not asserted — it is opt-in.
    {
        use smoothcache::obs::{self, TraceHandle, TraceLevel};
        let prev = obs::level();

        // per-call cost of an event on an inactive handle, batched so
        // clock granularity doesn't swamp single-digit nanoseconds
        let off_handle = TraceHandle::off();
        const EVENTS_PER_ITER: usize = 10_000;
        let ev_iters = if fast_mode() { 3 } else { 200 };
        let d = bench(2, ev_iters, || {
            for i in 0..EVENTS_PER_ITER {
                std::hint::black_box(&off_handle).event("obs_bench", i as u64, 0, 0, f64::NAN);
            }
        });
        let disabled_ns = d.min_s * 1e9 / EVENTS_PER_ITER as f64;
        assert!(
            disabled_ns < 50.0,
            "disabled trace event costs {disabled_ns:.1}ns/call — the off path must stay noise-level"
        );

        // the queue-decomposition burst again, once per level: fresh
        // coordinator each time (startup outside the timed window), one
        // warmup burst so plan/engine caches never count against a
        // level, min wall over the reps to shed scheduler noise
        let burst_wall = |coord: &Coordinator| -> smoothcache::util::error::Result<f64> {
            let t0 = std::time::Instant::now();
            let rxs: Vec<_> = (0..burst)
                .map(|i| {
                    coord.submit(Request {
                        id: 0,
                        family: "image".into(),
                        cond: Cond::Label(vec![(i % 10) as i32]),
                        solver: SolverKind::Ddim,
                        steps: qsteps,
                        cfg_scale: 1.0,
                        seed: i as u64,
                        policy: Policy::no_cache(),
                        compute: Default::default(),
                        priority: Default::default(),
                    })
                })
                .collect();
            for rx in rxs {
                rx.recv().unwrap()?;
            }
            Ok(t0.elapsed().as_secs_f64())
        };
        let wall_at = |lvl: TraceLevel| -> smoothcache::util::error::Result<f64> {
            obs::set_level(lvl);
            let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
            cfg.preload = vec!["image".into()];
            cfg.max_wait = Duration::from_millis(5);
            cfg.workers = 2;
            let coord = Coordinator::start(cfg)?;
            let _ = burst_wall(&coord)?;
            let reps = if fast_mode() { 2 } else { 3 };
            let mut best = f64::INFINITY;
            for _ in 0..reps {
                best = best.min(burst_wall(&coord)?);
            }
            coord.shutdown();
            Ok(best)
        };
        let off_s = wall_at(TraceLevel::Off)?;
        let coarse_s = wall_at(TraceLevel::Coarse)?;
        let fine_s = wall_at(TraceLevel::Fine)?;
        obs::set_level(prev);
        // floor at 0.05% so the recorded metric never lands on an exact
        // zero (a zero baseline makes every later diff an infinite move)
        let pct = |lvl_s: f64| ((lvl_s - off_s) / off_s * 100.0).max(0.05);
        let (coarse_pct, fine_pct) = (pct(coarse_s), pct(fine_s));
        assert!(
            coarse_pct < 3.0,
            "coarse tracing adds {coarse_pct:.2}% to the serving burst (must stay under 3%)"
        );
        let mut otable = Table::new(&["trace level", "burst wall (ms)", "overhead"]);
        otable.row(&["off".into(), format!("{:.2}", off_s * 1e3), "-".into()]);
        otable.row(&[
            "coarse (default)".into(),
            format!("{:.2}", coarse_s * 1e3),
            format!("{coarse_pct:.2}%"),
        ]);
        otable.row(&["fine".into(), format!("{:.2}", fine_s * 1e3), format!("{fine_pct:.2}%")]);
        println!(
            "\n§Perf — obs tracing overhead ({burst}-request no-cache burst, DDIM-{qsteps}, \
             disabled event {disabled_ns:.1}ns/call)"
        );
        otable.print();
        std::fs::write("bench_out/perf_engine_obs.csv", otable.to_csv())?;
        report.metric_tol("obs:overhead_pct", coarse_pct, "%", false, 5000.0)?;
        report.metric_tol("obs:overhead_fine_pct", fine_pct, "%", false, 5000.0)?;
        report.metric_tol("obs:disabled_ns_per_event", disabled_ns, "ns", false, 5000.0)?;
    }

    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
