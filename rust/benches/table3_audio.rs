//! Table 3 reproduction: audio family (Stable Audio Open proxy) under
//! DPM-Solver++(3M) SDE at 100 steps, CFG 7.0, across three prompt
//! suites standing in for AudioCaps / MusicCaps / Song Describer.
//! Metrics per suite: FD-proxy (vs the harmonic reference corpus),
//! KL-proxy and CLAP-proxy (vs paired no-cache generations) — DESIGN.md
//! section 3 documents each substitution.
//!
//! Flags: `--threads N`, `--smoke` (CI scale), `--json OUT`
//! (machine-readable report, docs/benchmarks.md).

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef, Schedule};
use smoothcache::experiments::{
    audio_corpus, eval_conds, fmt_pm, generate_set, mean_std, EvalConfig,
};
use smoothcache::macs::{as_gmacs, generation_macs};
use smoothcache::model::Engine;
use smoothcache::quality::{clap_proxy, ffd, kl_proxy, FeatureExtractor};
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{fast_mode, Args, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    // `--threads N` pins the GEMM pool per evaluation (0 = auto)
    let threads = args.usize("threads", 0)?;
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;
    engine.load_family("audio")?;
    let fm = engine.family_manifest("audio")?.clone();
    let bts = fm.branch_types.clone();
    let sites = fm.branch_sites();

    // DPM-Solver++(3M) needs solver history, so smoke keeps 6 steps
    let (steps, n_samples, calib_samples) = if smoke {
        (6usize, 4usize, 1usize)
    } else if fast_mode() {
        (10, 8, 2)
    } else {
        (100, 12, 10)
    };
    let solver = SolverKind::DpmPP3M { sde: true };
    let cfg_scale = 7.0f32;

    let mut report = BenchReport::new("table3_audio");
    report.meta("family", "audio");
    report.meta("solver", "dpmpp3m-sde");
    report.meta("steps", steps);
    report.meta("samples", n_samples);
    report.meta("threads", threads);
    report.meta("smoke", smoke);
    report.run_meta(0);

    eprintln!("[table3] calibrating dpmpp3m-sde-{steps} ...");
    let cc = CalibrationConfig {
        cfg_scale,
        num_samples: calib_samples,
        ..CalibrationConfig::new(solver, steps)
    };
    let curves = calibrate(&engine, "audio", &cc)?;

    // paper Table 3 MAC reductions: 209.8→170.8 ≈ 19%, 209.8→136.2 ≈ 35%
    let (a1, s1) = curves.alpha_for_skip_fraction(0.20, &bts);
    let (a2, s2) = curves.alpha_for_skip_fraction(0.37, &bts);

    let fx = FeatureExtractor::new(0xA0D10, 12);
    let corpus = audio_corpus(128, 0xFEED);
    let suites: [(&str, u64); 3] =
        [("AudioCaps-proxy", 101), ("MusicCaps-proxy", 202), ("SongDescriber-proxy", 303)];
    // stable per-suite metric key prefixes
    let suite_slugs = ["audiocaps", "musiccaps", "songdescriber"];

    // warmup (batch 4 × CFG → batch 8 executables)
    {
        let mut ec = EvalConfig::new("audio", solver, 2).with_threads(threads);
        ec.n_samples = 4;
        ec.cfg_scale = cfg_scale;
        let conds = eval_conds(&fm, 4, 1);
        let warm_plan = CachePlan::no_cache(2, &sites);
        let _ = generate_set(&engine, &ec, &conds, PlanRef::Plan(&warm_plan))?;
    }

    let mut header = vec!["Schedule".to_string()];
    for (suite, _) in &suites {
        header.push(format!("{suite} FD (dn)"));
        header.push(format!("{suite} KL (dn)"));
        header.push(format!("{suite} CLAP (up)"));
    }
    header.push("GMACs".into());
    header.push("Latency (s)".into());
    header.push("skip%".into());
    let hdr_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    // slugs keyed by target skip fraction, not calibrated alpha
    let roster: Vec<(&'static str, String, Schedule)> = vec![
        ("no_cache", "No Cache".into(), Schedule::no_cache(steps, &bts)),
        ("ours_s20", format!("Ours (a={a1:.3})"), s1),
        ("ours_s37", format!("Ours (a={a2:.3})"), s2),
    ];

    // reference (no-cache) sets per suite, paired seeds
    let mut refs = Vec::new();
    for (suite, seed) in &suites {
        let mut ec = EvalConfig::new("audio", solver, steps).with_threads(threads);
        ec.n_samples = n_samples;
        ec.cfg_scale = cfg_scale;
        ec.base_seed = 7000 + seed;
        let conds = eval_conds(&fm, n_samples, *seed);
        let no_cache = CachePlan::no_cache(steps, &sites);
        let (set, stats) = generate_set(&engine, &ec, &conds, PlanRef::Plan(&no_cache))?;
        eprintln!("[table3] reference set {suite}: done");
        refs.push((ec, conds, set, stats));
    }

    for (slug, name, schedule) in &roster {
        schedule.validate().unwrap();
        let plan = CachePlan::from_grouped(schedule, &sites)?;
        let gmacs = as_gmacs(generation_macs(&fm, schedule, true));
        let mut row = vec![name.clone()];
        let mut lats = Vec::new();
        for (si, (ec, conds, ref_set, ref_stats)) in refs.iter().enumerate() {
            let (set, stats) = if schedule.skip_fraction() == 0.0 {
                (ref_set.clone(), ref_stats.clone())
            } else {
                generate_set(&engine, ec, conds, PlanRef::Plan(&plan))?
            };
            let fd = ffd(&fx, &corpus, &set);
            let kl = kl_proxy(&fx, ref_set, &set, 10);
            let clap = clap_proxy(&fx, ref_set, &set);
            if json_out.is_some() {
                let suite = suite_slugs[si];
                report.metric_tol(&format!("{slug}/{suite}/fd"), fd, "score", false, 2.0)?;
                report.metric_tol(&format!("{slug}/{suite}/kl"), kl, "nats", false, 10.0)?;
                report.metric_tol(&format!("{slug}/{suite}/clap"), clap, "score", true, 2.0)?;
            }
            row.push(fmt_pm(fd, 0.0, 3));
            row.push(fmt_pm(kl, 0.0, 6));
            row.push(fmt_pm(clap, 0.0, 6));
            lats.push(stats.per_sample_seconds);
        }
        let (lm, _) = mean_std(&lats);
        if json_out.is_some() {
            report.metric_tol(&format!("{slug}/gmacs"), gmacs, "GMACs", false, 0.1)?;
            report.metric_tol(&format!("{slug}/latency_s"), lm, "s", false, 100.0)?;
            report.metric_tol(
                &format!("{slug}/skip_pct"),
                schedule.skip_fraction() * 100.0,
                "%",
                true,
                1.0,
            )?;
        }
        row.push(format!("{gmacs:.2}"));
        row.push(format!("{lm:.3}"));
        row.push(format!("{:.0}%", schedule.skip_fraction() * 100.0));
        table.row(&row);
        eprintln!("[table3] {name}: done");
    }

    println!(
        "\nTable 3 — audio family, DPM-Solver++(3M) SDE {steps} steps, CFG 7.0 \
         (paper: Stable Audio Open)"
    );
    table.print();
    std::fs::write("bench_out/table3_audio.csv", table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
