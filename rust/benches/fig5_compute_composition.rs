//! Fig. 5 reproduction: layer compute composition (MAC shares) of each
//! candidate model, and the ">90% of compute is cacheable" observation.
//!
//! Flags: `--smoke` (accepted for roster uniformity — this bench is
//! analytic and already instant) and `--json OUT` (machine-readable
//! report, docs/benchmarks.md).

use smoothcache::macs::{as_gmacs, cacheable_fraction, composition, forward_macs};
use smoothcache::model::Manifest;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{Args, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin geometry");
    }
    std::fs::create_dir_all("bench_out")?;
    let (manifest, _) = Manifest::load_or_builtin(&dir)?;

    let mut report = BenchReport::new("fig5");
    report.meta("smoke", smoke);
    report.run_meta(0);

    let mut table = Table::new(&["family", "component", "MAC share", "bar"]);
    let mut frac_table =
        Table::new(&["family", "forward GMACs", "cacheable fraction", "paper claim"]);

    for (name, fm) in &manifest.families {
        for (component, share) in composition(fm) {
            let bar = "#".repeat((share * 50.0).round() as usize);
            table.row(&[
                name.clone(),
                component,
                format!("{:.1}%", share * 100.0),
                bar,
            ]);
        }
        let frac = cacheable_fraction(fm);
        // analytic quantities — any drift means the MAC model changed
        report.metric_tol(&format!("{name}/cacheable_fraction"), frac, "frac", true, 0.1)?;
        report.metric_tol(
            &format!("{name}/forward_gmacs"),
            as_gmacs(forward_macs(fm)),
            "GMACs",
            false,
            0.1,
        )?;
        frac_table.row(&[
            name.clone(),
            format!("{:.4}", as_gmacs(forward_macs(fm))),
            format!("{:.1}%", frac * 100.0),
            if frac > 0.9 { ">=90% ok".into() } else { "BELOW 90%".to_string() },
        ]);
    }

    println!("\nFig. 5 — layer compute composition (MACs of one forward pass)");
    table.print();
    println!("\nCacheable-compute fraction (paper: 'at least 90% in all candidate models')");
    frac_table.print();
    std::fs::write("bench_out/fig5_composition.csv", table.to_csv())?;
    std::fs::write("bench_out/fig5_cacheable_fraction.csv", frac_table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
