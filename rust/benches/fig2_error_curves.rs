//! Fig. 2 reproduction: L1 relative-error curves of every architecture
//! component across timesteps, with 95% CIs from the calibration
//! samples, for all three model families under their paper solvers
//! (DDIM-50 / DPM++(3M)-SDE-100 / RF-30).
//!
//! Output: ASCII plots + `bench_out/fig2_<family>.csv` with columns
//! step, branch_type, k, mean, ci95.
//!
//! SMOOTHCACHE_BENCH_FAST=1 trims steps and samples.

use smoothcache::cache::{calibrate, paper_protocol};
use smoothcache::model::Engine;
use smoothcache::util::bench::{ascii_plot, fast_mode, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;

    let mut ci_table = Table::new(&["family", "solver", "steps", "samples", "mean CI width (k=1)"]);

    for family in ["image", "audio", "video"] {
        engine.load_family(family)?;
        let mut cc = paper_protocol(family);
        if fast_mode() {
            cc.steps = cc.steps.min(12);
            cc.num_samples = 3;
        } else {
            cc.num_samples = 10; // the paper's calibration-set size
        }
        let t0 = std::time::Instant::now();
        let curves = calibrate(&engine, family, &cc)?;
        eprintln!(
            "[fig2] calibrated {family} ({} steps x {} samples) in {:.1}s",
            cc.steps,
            cc.num_samples,
            t0.elapsed().as_secs_f64()
        );

        // CSV
        let mut csv = String::from("step,branch_type,k,mean,ci95\n");
        for bt in curves.branch_types() {
            for s in 0..cc.steps {
                for k in 1..=cc.k_max {
                    if let Some(m) = curves.mean(&bt, s, k) {
                        let acc = &curves.grouped[&bt][s][k - 1];
                        csv.push_str(&format!("{s},{bt},{k},{m},{}\n", acc.ci95()));
                    }
                }
            }
        }
        std::fs::write(format!("bench_out/fig2_{family}.csv"), &csv)?;

        // ASCII plot of k=1 curves per branch type
        let series: Vec<(String, Vec<f64>)> = curves
            .branch_types()
            .into_iter()
            .map(|bt| {
                let ys: Vec<f64> = (1..cc.steps)
                    .map(|s| curves.mean(&bt, s, 1).unwrap_or(0.0))
                    .collect();
                (bt, ys)
            })
            .collect();
        println!(
            "{}",
            ascii_plot(
                &format!(
                    "Fig.2 [{family}] L1 relative error (k=1) across {} {} steps",
                    cc.steps,
                    cc.solver.name()
                ),
                &series,
                12
            )
        );

        // the §3.3 observation: CI width predicts the pareto-front width
        for bt in curves.branch_types() {
            ci_table.row(&[
                family.into(),
                cc.solver.name().into(),
                cc.steps.to_string(),
                cc.num_samples.to_string(),
                format!("{:.5} ({bt})", curves.mean_ci_width(&bt)),
            ]);
        }

        // persist curves for reuse by other benches / the server
        std::fs::create_dir_all("bench_out/calibration")?;
        std::fs::write(
            format!("bench_out/calibration/{family}_{}_{}.json", cc.solver.name(), cc.steps),
            curves.to_json().to_string(),
        )?;
    }

    println!("Across-sample variability (paper §3.3: wider CI → narrower pareto front)");
    ci_table.print();
    std::fs::write("bench_out/fig2_ci_widths.csv", ci_table.to_csv())?;
    Ok(())
}
