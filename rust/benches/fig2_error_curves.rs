//! Fig. 2 reproduction: L1 relative-error curves of every architecture
//! component across timesteps, with 95% CIs from the calibration
//! samples, for all three model families under their paper solvers
//! (DDIM-50 / DPM++(3M)-SDE-100 / RF-30).
//!
//! Output: ASCII plots + `bench_out/fig2_<family>.csv` with columns
//! step, branch_type, k, mean, ci95.
//!
//! SMOOTHCACHE_BENCH_FAST=1 trims steps and samples; `--smoke` shrinks
//! to CI scale; `--json OUT` writes the machine-readable report
//! (docs/benchmarks.md).

use smoothcache::cache::{calibrate, paper_protocol};
use smoothcache::model::Engine;
use smoothcache::util::bench::report::BenchReport;
use smoothcache::util::bench::{ascii_plot, fast_mode, Args, Table};

fn main() -> smoothcache::util::error::Result<()> {
    let args = Args::parse();
    let smoke = args.flag("smoke")?;
    let json_out = args.str_opt("json")?;
    args.finish()?;

    let dir = smoothcache::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("note: no artifacts in {dir:?} — using the builtin reference backend");
    }
    std::fs::create_dir_all("bench_out")?;
    let mut engine = Engine::open(dir)?;

    let mut report = BenchReport::new("fig2");
    report.meta("smoke", smoke);
    report.run_meta(0);

    let mut ci_table = Table::new(&["family", "solver", "steps", "samples", "mean CI width (k=1)"]);

    for family in ["image", "audio", "video"] {
        engine.load_family(family)?;
        let mut cc = paper_protocol(family);
        if smoke {
            // DPM++(3M) needs solver history, so keep at least 6 steps
            cc.steps = cc.steps.min(6);
            cc.num_samples = 2;
        } else if fast_mode() {
            cc.steps = cc.steps.min(12);
            cc.num_samples = 3;
        } else {
            cc.num_samples = 10; // the paper's calibration-set size
        }
        let t0 = std::time::Instant::now();
        let curves = calibrate(&engine, family, &cc)?;
        let calib_s = t0.elapsed().as_secs_f64();
        eprintln!(
            "[fig2] calibrated {family} ({} steps x {} samples) in {calib_s:.1}s",
            cc.steps, cc.num_samples,
        );

        // CSV
        let mut csv = String::from("step,branch_type,k,mean,ci95\n");
        for bt in curves.branch_types() {
            for s in 0..cc.steps {
                for k in 1..=cc.k_max {
                    if let Some(m) = curves.mean(&bt, s, k) {
                        let acc = &curves.grouped[&bt][s][k - 1];
                        csv.push_str(&format!("{s},{bt},{k},{m},{}\n", acc.ci95()));
                    }
                }
            }
        }
        std::fs::write(format!("bench_out/fig2_{family}.csv"), &csv)?;

        // ASCII plot of k=1 curves per branch type
        let series: Vec<(String, Vec<f64>)> = curves
            .branch_types()
            .into_iter()
            .map(|bt| {
                let ys: Vec<f64> = (1..cc.steps)
                    .map(|s| curves.mean(&bt, s, 1).unwrap_or(0.0))
                    .collect();
                (bt, ys)
            })
            .collect();
        println!(
            "{}",
            ascii_plot(
                &format!(
                    "Fig.2 [{family}] L1 relative error (k=1) across {} {} steps",
                    cc.steps,
                    cc.solver.name()
                ),
                &series,
                12
            )
        );

        // the §3.3 observation: CI width predicts the pareto-front width
        let mut widths = Vec::new();
        for bt in curves.branch_types() {
            widths.push(curves.mean_ci_width(&bt));
            ci_table.row(&[
                family.into(),
                cc.solver.name().into(),
                cc.steps.to_string(),
                cc.num_samples.to_string(),
                format!("{:.5} ({bt})", curves.mean_ci_width(&bt)),
            ]);
        }
        if json_out.is_some() {
            let mean_width = widths.iter().sum::<f64>() / widths.len().max(1) as f64;
            report.metric_tol(&format!("{family}/mean_ci_width"), mean_width, "L1", false, 10.0)?;
            report.metric_tol(&format!("{family}/calib_s"), calib_s, "s", false, 150.0)?;
        }

        // persist curves for reuse by other benches / the server
        std::fs::create_dir_all("bench_out/calibration")?;
        std::fs::write(
            format!("bench_out/calibration/{family}_{}_{}.json", cc.solver.name(), cc.steps),
            curves.to_json().to_string(),
        )?;
    }

    println!("Across-sample variability (paper §3.3: wider CI → narrower pareto front)");
    ci_table.print();
    std::fs::write("bench_out/fig2_ci_widths.csv", ci_table.to_csv())?;
    if let Some(path) = &json_out {
        report.save(path)?;
        println!("wrote bench report: {path}");
    }
    Ok(())
}
