//! Backend-seam contract tests: the reference backend must be
//! deterministic across engine instances (weights are synthesized from
//! seeds, not loaded from disk), and caching policies must change
//! branch-execution counts exactly as the paper's mechanism predicts
//! (no-cache = every site every step; FORA-n computes on every n-th
//! step; SmoothCache computes monotonically less as α grows, bounded by
//! k_max).

use smoothcache::cache::{calibrate, CalibrationConfig, Schedule};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, CacheMode, GenConfig, GenStats};
use smoothcache::solvers::SolverKind;

const STEPS: usize = 10;

fn engine() -> Engine {
    let mut e = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    e.load_family("image").expect("load image");
    e
}

fn run(engine: &Engine, mode: &CacheMode) -> (Vec<f32>, GenStats) {
    let cfg = GenConfig::new("image", SolverKind::Ddim, STEPS).with_seed(21);
    let out = generate(engine, &cfg, &Cond::Label(vec![5]), mode, None).expect("generate");
    (out.latent.data, out.stats)
}

#[test]
fn reference_backend_is_deterministic_across_instances() {
    // two completely independent engines (fresh backend, fresh
    // synthesized weights) must agree bit-for-bit
    let (a, sa) = run(&engine(), &CacheMode::None);
    let (b, sb) = run(&engine(), &CacheMode::None);
    assert_eq!(a, b, "same seed, fresh engine → identical latents");
    assert_eq!(sa.branch_computes, sb.branch_computes);
    assert!(a.iter().all(|v| v.is_finite()));
}

#[test]
fn no_cache_executes_every_site_every_step() {
    let e = engine();
    let fm = e.family_manifest("image").unwrap().clone();
    let sites = fm.depth * fm.branch_types.len();
    let (_, stats) = run(&e, &CacheMode::None);
    assert_eq!(stats.branch_computes, STEPS * sites);
    assert_eq!(stats.branch_reuses, 0);
}

#[test]
fn fora_halves_branch_executions() {
    let e = engine();
    let fm = e.family_manifest("image").unwrap().clone();
    let sites = fm.depth * fm.branch_types.len();
    let schedule = Schedule::fora(STEPS, &fm.branch_types, 2);
    let (_, stats) = run(&e, &CacheMode::Grouped(&schedule));
    // n=2 over 10 steps: compute on steps 0,2,4,6,8 → half the work
    assert_eq!(stats.branch_computes, STEPS / 2 * sites);
    assert_eq!(stats.branch_reuses, STEPS / 2 * sites);
}

#[test]
fn smoothcache_alpha_monotonically_trades_compute() {
    let e = engine();
    let fm = e.family_manifest("image").unwrap().clone();
    let sites = fm.depth * fm.branch_types.len();
    let cc = CalibrationConfig {
        steps: STEPS,
        num_samples: 2,
        k_max: 3,
        ..CalibrationConfig::new(SolverKind::Ddim, STEPS)
    };
    let curves = calibrate(&e, "image", &cc).expect("calibrate");

    // α = 0 admits no reuse at all (every calibrated error exceeds it)
    let s0 = curves.smoothcache_schedule(0.0, &fm.branch_types);
    let (_, stats0) = run(&e, &CacheMode::Grouped(&s0));
    assert_eq!(stats0.branch_computes, STEPS * sites);

    // compute count is non-increasing in α …
    let mut prev = usize::MAX;
    let mut counts = Vec::new();
    for alpha in [0.0, 0.3, 1.5, 1e9] {
        let s = curves.smoothcache_schedule(alpha, &fm.branch_types);
        s.validate().expect("valid schedule");
        assert!(s.max_gap() <= cc.k_max, "gap bounded by k_max");
        let (_, stats) = run(&e, &CacheMode::Grouped(&s));
        assert_eq!(
            stats.branch_computes + stats.branch_reuses,
            STEPS * sites,
            "every site is either computed or reused"
        );
        assert!(stats.branch_computes <= prev, "alpha={alpha}");
        prev = stats.branch_computes;
        counts.push(stats.branch_computes);
    }
    // … and an unbounded α must actually reuse something: step 1 always
    // has a populated k=1 cell below it
    assert!(
        *counts.last().unwrap() < STEPS * sites,
        "α=1e9 produced no reuse: {counts:?}"
    );
    // with k_max = 3 at least one compute per 4 steps survives
    assert!(*counts.last().unwrap() >= (STEPS / 4) * sites / 2);
}

#[test]
fn distinct_families_share_one_engine() {
    let mut e = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    e.load_family("image").expect("image");
    e.load_family("audio").expect("audio");
    assert!(e.is_loaded("image") && e.is_loaded("audio"));
    let img = GenConfig::new("image", SolverKind::Ddim, 2).with_seed(1);
    let aud = GenConfig::new("audio", SolverKind::Ddim, 2).with_seed(1);
    let gi = generate(&e, &img, &Cond::Label(vec![0]), &CacheMode::None, None).unwrap();
    let ga = generate(&e, &aud, &Cond::Prompt(vec![3; 8]), &CacheMode::None, None).unwrap();
    assert_eq!(gi.latent.shape, vec![1, 16, 16, 4]);
    assert_eq!(ga.latent.shape, vec![1, 64, 8]);
}
