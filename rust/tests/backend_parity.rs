//! Backend-seam contract tests: the reference backend must be
//! deterministic across engine instances (weights are synthesized from
//! seeds, not loaded from disk), and caching policies must change
//! branch-execution counts exactly as the paper's mechanism predicts
//! (no-cache = every site every step; FORA-n computes on every n-th
//! step; SmoothCache computes monotonically less as α grows, bounded by
//! k_max).

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef, Schedule};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, GenConfig, GenStats};
use smoothcache::solvers::SolverKind;

const STEPS: usize = 10;

fn engine() -> Engine {
    let mut e = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    e.load_family("image").expect("load image");
    e
}

fn run(engine: &Engine, plan: PlanRef<'_>) -> (Vec<f32>, GenStats) {
    let cfg = GenConfig::new("image", SolverKind::Ddim, STEPS).with_seed(21);
    let out = generate(engine, &cfg, &Cond::Label(vec![5]), plan, None).expect("generate");
    (out.latent.data, out.stats)
}

fn no_cache_plan(engine: &Engine) -> CachePlan {
    let fm = engine.family_manifest("image").unwrap();
    CachePlan::no_cache(STEPS, &fm.branch_sites())
}

#[test]
fn reference_backend_is_deterministic_across_instances() {
    // two completely independent engines (fresh backend, fresh
    // synthesized weights) must agree bit-for-bit
    let e1 = engine();
    let e2 = engine();
    let (a, sa) = run(&e1, PlanRef::Plan(&no_cache_plan(&e1)));
    let (b, sb) = run(&e2, PlanRef::Plan(&no_cache_plan(&e2)));
    assert_eq!(a, b, "same seed, fresh engine → identical latents");
    assert_eq!(sa.branch_computes, sb.branch_computes);
    assert!(a.iter().all(|v| v.is_finite()));
}

#[test]
fn no_cache_executes_every_site_every_step() {
    let e = engine();
    let fm = e.family_manifest("image").unwrap().clone();
    let sites = fm.depth * fm.branch_types.len();
    let (_, stats) = run(&e, PlanRef::Plan(&no_cache_plan(&e)));
    assert_eq!(stats.branch_computes, STEPS * sites);
    assert_eq!(stats.branch_reuses, 0);
}

#[test]
fn fora_halves_branch_executions() {
    let e = engine();
    let fm = e.family_manifest("image").unwrap().clone();
    let sites = fm.depth * fm.branch_types.len();
    let schedule = Schedule::fora(STEPS, &fm.branch_types, 2);
    let plan = CachePlan::from_grouped(&schedule, &fm.branch_sites()).unwrap();
    let (_, stats) = run(&e, PlanRef::Plan(&plan));
    // n=2 over 10 steps: compute on steps 0,2,4,6,8 → half the work
    assert_eq!(stats.branch_computes, STEPS / 2 * sites);
    assert_eq!(stats.branch_reuses, STEPS / 2 * sites);
}

#[test]
fn smoothcache_alpha_monotonically_trades_compute() {
    let e = engine();
    let fm = e.family_manifest("image").unwrap().clone();
    let sites = fm.depth * fm.branch_types.len();
    let cc = CalibrationConfig {
        steps: STEPS,
        num_samples: 2,
        k_max: 3,
        ..CalibrationConfig::new(SolverKind::Ddim, STEPS)
    };
    let curves = calibrate(&e, "image", &cc).expect("calibrate");

    // α = 0 admits no reuse at all (every calibrated error exceeds it)
    let s0 = curves.smoothcache_schedule(0.0, &fm.branch_types);
    let p0 = CachePlan::from_grouped(&s0, &fm.branch_sites()).unwrap();
    let (_, stats0) = run(&e, PlanRef::Plan(&p0));
    assert_eq!(stats0.branch_computes, STEPS * sites);

    // compute count is non-increasing in α …
    let mut prev = usize::MAX;
    let mut counts = Vec::new();
    for alpha in [0.0, 0.3, 1.5, 1e9] {
        let s = curves.smoothcache_schedule(alpha, &fm.branch_types);
        s.validate().expect("valid schedule");
        assert!(s.max_gap() <= cc.k_max, "gap bounded by k_max");
        let p = CachePlan::from_grouped(&s, &fm.branch_sites()).unwrap();
        let (_, stats) = run(&e, PlanRef::Plan(&p));
        assert_eq!(
            stats.branch_computes + stats.branch_reuses,
            STEPS * sites,
            "every site is either computed or reused"
        );
        assert!(stats.branch_computes <= prev, "alpha={alpha}");
        prev = stats.branch_computes;
        counts.push(stats.branch_computes);
    }
    // … and an unbounded α must actually reuse something: step 1 always
    // has a populated k=1 cell below it
    assert!(
        *counts.last().unwrap() < STEPS * sites,
        "α=1e9 produced no reuse: {counts:?}"
    );
    // with k_max = 3 at least one compute per 4 steps survives
    assert!(*counts.last().unwrap() >= (STEPS / 4) * sites / 2);
}

#[test]
fn distinct_families_share_one_engine() {
    let mut e = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    e.load_family("image").expect("image");
    e.load_family("audio").expect("audio");
    assert!(e.is_loaded("image") && e.is_loaded("audio"));
    let img = GenConfig::new("image", SolverKind::Ddim, 2).with_seed(1);
    let aud = GenConfig::new("audio", SolverKind::Ddim, 2).with_seed(1);
    let nc_img = CachePlan::no_cache(2, &e.family_manifest("image").unwrap().branch_sites());
    let nc_aud = CachePlan::no_cache(2, &e.family_manifest("audio").unwrap().branch_sites());
    let gi = generate(&e, &img, &Cond::Label(vec![0]), PlanRef::Plan(&nc_img), None).unwrap();
    let ga = generate(&e, &aud, &Cond::Prompt(vec![3; 8]), PlanRef::Plan(&nc_aud), None).unwrap();
    assert_eq!(gi.latent.shape, vec![1, 16, 16, 4]);
    assert_eq!(ga.latent.shape, vec![1, 64, 8]);
}
