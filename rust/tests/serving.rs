//! Serving-stack integration: coordinator batching + TCP server + client
//! over the engine-selected backend (pure-Rust reference offline).

use std::sync::Arc;
use std::time::Duration;

use smoothcache::coordinator::{Coordinator, CoordinatorConfig, Metrics, Policy, Request};
use smoothcache::model::Cond;
use smoothcache::server::{Client, Server};
use smoothcache::solvers::SolverKind;
use smoothcache::util::json::Json;

fn coord() -> Coordinator {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(10);
    cfg.calib_samples = 2;
    Coordinator::start(cfg).expect("coordinator")
}

fn image_request(seed: u64, policy: Policy) -> Request {
    Request {
        id: 0,
        family: "image".into(),
        cond: Cond::Label(vec![(seed % 10) as i32]),
        solver: SolverKind::Ddim,
        steps: 8,
        cfg_scale: 1.0,
        seed,
        policy,
    }
}

#[test]
fn coordinator_serves_single_request() {
    let c = coord();
    let resp = c.generate_blocking(image_request(1, Policy::no_cache())).expect("response");
    assert_eq!(resp.latent.shape, vec![1, 16, 16, 4]);
    assert!(resp.total_seconds > 0.0);
    assert_eq!(Metrics::get(&c.metrics().requests_completed), 1);
    c.shutdown();
}

#[test]
fn coordinator_batches_concurrent_requests() {
    let c = coord();
    // submit 4 compatible requests back-to-back; the batcher should
    // group them (max_wait 10ms) into ≤ 2 batches rather than 4.
    let rxs: Vec<_> = (0..4)
        .map(|i| c.submit(image_request(100 + i, Policy::fora(2))))
        .collect();
    let mut sizes = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap().expect("ok");
        sizes.push(resp.batch_size);
    }
    assert!(
        sizes.iter().any(|&s| s >= 2),
        "expected some batching, got sizes {sizes:?}"
    );
    let batches = Metrics::get(&c.metrics().batches_executed);
    assert!(batches <= 3, "batches={batches}");
    // FORA(2) must have produced real skips
    assert!(Metrics::get(&c.metrics().branch_reuses) > 0);
    c.shutdown();
}

#[test]
fn batched_result_matches_solo_result() {
    let c = coord();
    // run one request alone...
    let solo = c.generate_blocking(image_request(7, Policy::no_cache())).unwrap();
    // ...then the same seed inside a concurrent burst
    let rxs: Vec<_> = [7u64, 8, 9, 10]
        .iter()
        .map(|&s| c.submit(image_request(s, Policy::no_cache())))
        .collect();
    let batched: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let same = &batched[0];
    assert_eq!(solo.latent.shape, same.latent.shape);
    // identical seeds → identical latents regardless of batch composition
    let max_err = solo
        .latent
        .data
        .iter()
        .zip(&same.latent.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-4, "batch composition changed the result: {max_err}");
    c.shutdown();
}

#[test]
fn smoothcache_policy_calibrates_once_and_skips() {
    let c = coord();
    // a generous alpha: any populated error cell below it triggers
    // reuse, so skips are guaranteed without pinning the (untrained)
    // model's absolute error scale
    let r1 = c.generate_blocking(image_request(1, Policy::smooth(2.0))).unwrap();
    let r2 = c.generate_blocking(image_request(2, Policy::smooth(2.0))).unwrap();
    assert!(r1.gen_stats.skip_fraction() > 0.0, "alpha 2.0 should skip");
    assert_eq!(r1.gen_stats.skip_fraction(), r2.gen_stats.skip_fraction());
    // calibration ran exactly once (cached for the second request)
    assert_eq!(Metrics::get(&c.metrics().calibrations), 1);
    c.shutdown();
}

#[test]
fn dynamic_drift_policy_serves_deterministically_without_calibration() {
    let c = coord();
    // a generous bound: once a site has measured any drift it keeps
    // reusing until the gap cap, so skips are guaranteed without
    // pinning the untrained model's absolute drift scale
    let r1 = c.generate_blocking(image_request(1, Policy::drift(1e9))).unwrap();
    let r2 = c.generate_blocking(image_request(1, Policy::drift(1e9))).unwrap();
    assert!(r1.gen_stats.skip_fraction() > 0.0, "drift:1e9 should skip");
    // same request → identical runtime decisions (pure function of the
    // trajectory) and identical latents
    assert_eq!(r1.gen_stats.branch_computes, r2.gen_stats.branch_computes);
    assert_eq!(r1.latent.data, r2.latent.data);
    // dynamic policies never calibrate and never touch the plan store
    assert_eq!(Metrics::get(&c.metrics().calibrations), 0);
    assert_eq!(Metrics::get(&c.metrics().plan_cache_misses), 0);
    c.shutdown();
}

#[test]
fn smooth_policy_plan_is_cached_across_requests() {
    let c = coord();
    let _ = c.generate_blocking(image_request(1, Policy::smooth(2.0))).unwrap();
    let _ = c.generate_blocking(image_request(2, Policy::smooth(2.0))).unwrap();
    // first request builds the plan, second hits the PlanKey cache
    assert_eq!(Metrics::get(&c.metrics().plan_cache_misses), 1);
    assert!(Metrics::get(&c.metrics().plan_cache_hits) >= 1);
    c.shutdown();
}

#[test]
fn server_round_trip() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut client = Client::connect(&server.addr).expect("client");
    assert!(client.ping().unwrap());

    let req = Json::obj()
        .set("family", "image")
        .set("label", 4.0)
        .set("steps", 6usize)
        .set("solver", "ddim")
        .set("policy", "fora:2")
        .set("seed", 11u64)
        .set("return_latent", true);
    let resp = client.call(&req).expect("call");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    assert_eq!(
        resp.get("latent_shape").unwrap().as_usize_vec().unwrap(),
        vec![1, 16, 16, 4]
    );
    let latent = resp.get("latent").unwrap().as_f32_vec().unwrap();
    assert_eq!(latent.len(), 16 * 16 * 4);
    assert!(resp.get("skip_fraction").unwrap().as_f64().unwrap() > 0.0);

    let summary = client.metrics_summary().unwrap();
    assert!(summary.contains("completed=1"), "{summary}");

    // a dynamic policy serves over the wire like any other
    let dyn_req = Json::obj()
        .set("family", "image")
        .set("label", 2.0)
        .set("steps", 6usize)
        .set("policy", "drift:1e9")
        .set("seed", 5u64);
    let dyn_resp = client.call(&dyn_req).expect("drift call");
    assert_eq!(dyn_resp.get("ok").unwrap().as_bool(), Some(true), "{dyn_resp:?}");
    assert!(dyn_resp.get("skip_fraction").unwrap().as_f64().unwrap() > 0.0);

    // malformed request is answered, not dropped
    let bad = client.call(&Json::obj().set("family", "image")).unwrap();
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));

    // malformed policy parameters are answered with an error, not a
    // panicked executor
    let bad_pol = client
        .call(&Json::obj().set("family", "image").set("label", 1.0).set("policy", "fora:0"))
        .unwrap();
    assert_eq!(bad_pol.get("ok").unwrap().as_bool(), Some(false));

    server.stop();
}
