//! Serving-stack integration: coordinator batching + TCP server + client
//! over the engine-selected backend (pure-Rust reference offline),
//! including the streaming/cancellation surfaces (ISSUE 5): per-step
//! event lines, `{"cmd":"cancel"}`, cancel-on-disconnect, and deadline
//! rejection — with metrics that reconcile afterwards.

use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use smoothcache::coordinator::{
    Coordinator, CoordinatorConfig, Metrics, Policy, PriorityClass, Request,
};
use smoothcache::model::Cond;
use smoothcache::server::{Client, Server};
use smoothcache::solvers::SolverKind;
use smoothcache::util::json::Json;

fn coord() -> Coordinator {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(10);
    cfg.calib_samples = 2;
    Coordinator::start(cfg).expect("coordinator")
}

fn image_request(seed: u64, policy: Policy) -> Request {
    Request {
        id: 0,
        family: "image".into(),
        cond: Cond::Label(vec![(seed % 10) as i32]),
        solver: SolverKind::Ddim,
        steps: 8,
        cfg_scale: 1.0,
        seed,
        policy,
        compute: Default::default(),
        priority: Default::default(),
    }
}

#[test]
fn coordinator_serves_single_request() {
    let c = coord();
    let resp = c.generate_blocking(image_request(1, Policy::no_cache())).expect("response");
    assert_eq!(resp.latent.shape, vec![1, 16, 16, 4]);
    assert!(resp.total_seconds > 0.0);
    assert_eq!(Metrics::get(&c.metrics().requests_completed), 1);
    c.shutdown();
}

#[test]
fn coordinator_batches_concurrent_requests() {
    let c = coord();
    // submit 4 compatible requests back-to-back; the batcher should
    // group them (max_wait 10ms) into ≤ 2 batches rather than 4.
    let rxs: Vec<_> = (0..4)
        .map(|i| c.submit(image_request(100 + i, Policy::fora(2))))
        .collect();
    let mut sizes = Vec::new();
    for rx in rxs {
        let resp = rx.recv().unwrap().expect("ok");
        sizes.push(resp.batch_size);
    }
    assert!(
        sizes.iter().any(|&s| s >= 2),
        "expected some batching, got sizes {sizes:?}"
    );
    let batches = Metrics::get(&c.metrics().batches_executed);
    assert!(batches <= 3, "batches={batches}");
    // FORA(2) must have produced real skips
    assert!(Metrics::get(&c.metrics().branch_reuses) > 0);
    c.shutdown();
}

#[test]
fn batched_result_matches_solo_result() {
    let c = coord();
    // run one request alone...
    let solo = c.generate_blocking(image_request(7, Policy::no_cache())).unwrap();
    // ...then the same seed inside a concurrent burst
    let rxs: Vec<_> = [7u64, 8, 9, 10]
        .iter()
        .map(|&s| c.submit(image_request(s, Policy::no_cache())))
        .collect();
    let batched: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
    let same = &batched[0];
    assert_eq!(solo.latent.shape, same.latent.shape);
    // identical seeds → identical latents regardless of batch composition
    let max_err = solo
        .latent
        .data
        .iter()
        .zip(&same.latent.data)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_err < 1e-4, "batch composition changed the result: {max_err}");
    c.shutdown();
}

#[test]
fn smoothcache_policy_calibrates_once_and_skips() {
    let c = coord();
    // a generous alpha: any populated error cell below it triggers
    // reuse, so skips are guaranteed without pinning the (untrained)
    // model's absolute error scale
    let r1 = c.generate_blocking(image_request(1, Policy::smooth(2.0))).unwrap();
    let r2 = c.generate_blocking(image_request(2, Policy::smooth(2.0))).unwrap();
    assert!(r1.gen_stats.skip_fraction() > 0.0, "alpha 2.0 should skip");
    assert_eq!(r1.gen_stats.skip_fraction(), r2.gen_stats.skip_fraction());
    // calibration ran exactly once (cached for the second request)
    assert_eq!(Metrics::get(&c.metrics().calibrations), 1);
    c.shutdown();
}

#[test]
fn dynamic_drift_policy_serves_deterministically_without_calibration() {
    let c = coord();
    // a generous bound: once a site has measured any drift it keeps
    // reusing until the gap cap, so skips are guaranteed without
    // pinning the untrained model's absolute drift scale
    let r1 = c.generate_blocking(image_request(1, Policy::drift(1e9))).unwrap();
    let r2 = c.generate_blocking(image_request(1, Policy::drift(1e9))).unwrap();
    assert!(r1.gen_stats.skip_fraction() > 0.0, "drift:1e9 should skip");
    // same request → identical runtime decisions (pure function of the
    // trajectory) and identical latents
    assert_eq!(r1.gen_stats.branch_computes, r2.gen_stats.branch_computes);
    assert_eq!(r1.latent.data, r2.latent.data);
    // dynamic policies never calibrate and never touch the plan store
    assert_eq!(Metrics::get(&c.metrics().calibrations), 0);
    assert_eq!(Metrics::get(&c.metrics().plan_cache_misses), 0);
    c.shutdown();
}

#[test]
fn smooth_policy_plan_is_cached_across_requests() {
    let c = coord();
    let _ = c.generate_blocking(image_request(1, Policy::smooth(2.0))).unwrap();
    let _ = c.generate_blocking(image_request(2, Policy::smooth(2.0))).unwrap();
    // first request builds the plan, second hits the PlanKey cache
    assert_eq!(Metrics::get(&c.metrics().plan_cache_misses), 1);
    assert!(Metrics::get(&c.metrics().plan_cache_hits) >= 1);
    c.shutdown();
}

#[test]
fn server_round_trip() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut client = Client::connect(&server.addr).expect("client");
    assert!(client.ping().unwrap());

    let req = Json::obj()
        .set("family", "image")
        .set("label", 4.0)
        .set("steps", 6usize)
        .set("solver", "ddim")
        .set("policy", "fora:2")
        .set("seed", 11u64)
        .set("return_latent", true);
    let resp = client.call(&req).expect("call");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    assert_eq!(
        resp.get("latent_shape").unwrap().as_usize_vec().unwrap(),
        vec![1, 16, 16, 4]
    );
    let latent = resp.get("latent").unwrap().as_f32_vec().unwrap();
    assert_eq!(latent.len(), 16 * 16 * 4);
    assert!(resp.get("skip_fraction").unwrap().as_f64().unwrap() > 0.0);

    let summary = client.metrics_summary().unwrap();
    assert!(summary.contains("completed=1"), "{summary}");

    // a dynamic policy serves over the wire like any other
    let dyn_req = Json::obj()
        .set("family", "image")
        .set("label", 2.0)
        .set("steps", 6usize)
        .set("policy", "drift:1e9")
        .set("seed", 5u64);
    let dyn_resp = client.call(&dyn_req).expect("drift call");
    assert_eq!(dyn_resp.get("ok").unwrap().as_bool(), Some(true), "{dyn_resp:?}");
    assert!(dyn_resp.get("skip_fraction").unwrap().as_f64().unwrap() > 0.0);

    // malformed request is answered, not dropped
    let bad = client.call(&Json::obj().set("family", "image")).unwrap();
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false));

    // malformed policy parameters are answered with an error, not a
    // panicked executor
    let bad_pol = client
        .call(&Json::obj().set("family", "image").set("label", 1.0).set("policy", "fora:0"))
        .unwrap();
    assert_eq!(bad_pol.get("ok").unwrap().as_bool(), Some(false));

    // seeds that an `as u64` cast would have silently mangled are wire
    // errors now (lossless-integer contract, docs/protocol.md)
    for bad_seed in ["-3", "1.5", "18446744073709551615"] {
        let bad = client
            .call(&parse_json(&format!(
                r#"{{"family":"image","label":1,"seed":{bad_seed}}}"#
            )))
            .unwrap();
        assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "seed {bad_seed}");
        assert!(
            bad.get("error").and_then(|v| v.as_str()).unwrap_or("").contains("seed"),
            "seed {bad_seed}: {bad:?}"
        );
    }

    server.stop();
}

fn parse_json(s: &str) -> Json {
    smoothcache::util::json::parse(s).expect("test json")
}

#[test]
fn server_streams_step_events_and_matches_blocking_result() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mk = || {
        Json::obj()
            .set("family", "image")
            .set("label", 4.0)
            .set("steps", 6usize)
            .set("policy", "fora:2")
            .set("seed", 11u64)
            .set("return_latent", true)
    };

    // blocking reference result first
    let mut blocking = Client::connect(&server.addr).expect("client");
    let reference = blocking.call(&mk()).expect("blocking call");
    assert_eq!(reference.get("ok").unwrap().as_bool(), Some(true), "{reference:?}");

    // streamed run: an accepted line, one step line per solver step
    // (in order), then a final result line with the same latent
    let mut streaming = Client::connect(&server.addr).expect("client");
    let mut accepted = 0usize;
    let mut steps_seen = Vec::new();
    let done = streaming
        .call_streaming(&mk(), |ev| {
            match ev.get("event").and_then(|v| v.as_str()) {
                Some("accepted") => {
                    accepted += 1;
                    assert!(ev.get("id").and_then(|v| v.as_u64()).is_some(), "{ev:?}");
                }
                Some("step") => {
                    steps_seen.push(ev.get("step").and_then(|v| v.as_u64()).unwrap());
                    assert_eq!(ev.get("steps").and_then(|v| v.as_u64()), Some(6));
                    let c = ev.get("computes").and_then(|v| v.as_u64()).unwrap();
                    let r = ev.get("reuses").and_then(|v| v.as_u64()).unwrap();
                    assert!(c + r > 0, "{ev:?}");
                    assert!(ev.get("t_s").and_then(|v| v.as_f64()).is_some());
                }
                other => panic!("unexpected event {other:?}: {ev:?}"),
            }
        })
        .expect("streaming call");
    assert_eq!(accepted, 1);
    assert_eq!(steps_seen, vec![0, 1, 2, 3, 4, 5], "one ordered event per step");
    assert_eq!(done.get("event").and_then(|v| v.as_str()), Some("done"));
    assert_eq!(done.get("ok").unwrap().as_bool(), Some(true), "{done:?}");
    assert_eq!(done.get("steps").and_then(|v| v.as_u64()), Some(6));
    assert_eq!(
        done.get("latent").unwrap().as_f32_vec().unwrap(),
        reference.get("latent").unwrap().as_f32_vec().unwrap(),
        "streaming must not change the generated latent"
    );
    server.stop();
}

#[test]
fn server_cancel_command_aborts_inflight_generation() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut streaming = Client::connect(&server.addr).expect("client");
    let mut killer = Client::connect(&server.addr).expect("client");

    // long enough that cancellation always lands mid-flight
    let req = Json::obj()
        .set("family", "image")
        .set("label", 1.0)
        .set("steps", 2000usize)
        .set("policy", "no-cache")
        .set("seed", 5u64);
    let mut cancelled_at: Option<u64> = None;
    let outcome = streaming
        .call_streaming(&req, |ev| {
            // cancel from a sibling connection on the first step event
            if ev.get("event").and_then(|v| v.as_str()) == Some("step") && cancelled_at.is_none() {
                let id = ev.get("id").and_then(|v| v.as_u64()).unwrap();
                assert!(killer.cancel(id).expect("cancel rpc"), "id must be known");
                cancelled_at = Some(id);
            }
        })
        .expect("streaming call");
    assert!(cancelled_at.is_some(), "never saw a step event");
    assert_eq!(outcome.get("ok").unwrap().as_bool(), Some(false), "{outcome:?}");
    assert_eq!(outcome.get("cancelled").and_then(|v| v.as_bool()), Some(true), "{outcome:?}");

    // the stack is still healthy: counters reconcile and new work runs
    let summary = killer.metrics_summary().unwrap();
    assert!(summary.contains("cancelled=1"), "{summary}");
    assert!(summary.contains("completed=0"), "{summary}");
    let after = killer
        .call(&Json::obj().set("family", "image").set("label", 2.0).set("steps", 4usize))
        .unwrap();
    assert_eq!(after.get("ok").unwrap().as_bool(), Some(true), "{after:?}");
    // cancelling a finished id is a no-op answered with cancelled=false
    assert!(!killer.cancel(cancelled_at.unwrap()).unwrap());
    server.stop();
}

#[test]
fn server_cancels_on_disconnect() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");

    // fire a long request and slam the connection shut without reading
    {
        let mut stream = TcpStream::connect(server.addr).expect("connect");
        let req = Json::obj()
            .set("family", "image")
            .set("label", 0.0)
            .set("steps", 2000usize)
            .set("policy", "no-cache")
            .set("seed", 3u64);
        stream.write_all(req.to_string().as_bytes()).unwrap();
        stream.write_all(b"\n").unwrap();
        stream.flush().unwrap();
        // wait until the request is demonstrably executing
        let t0 = Instant::now();
        while Metrics::get(&c.metrics().steps_executed) == 0 {
            assert!(t0.elapsed() < Duration::from_secs(120), "generation never started");
            std::thread::sleep(Duration::from_millis(5));
        }
    } // drop = TCP close while the generation is mid-flight

    // the server notices the disconnect and cancels the orphaned work
    let mut probe = Client::connect(&server.addr).expect("client");
    let t0 = Instant::now();
    loop {
        let summary = probe.metrics_summary().unwrap();
        if summary.contains("cancelled=1") {
            assert!(summary.contains("completed=0"), "{summary}");
            break;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "disconnect never cancelled the request: {summary}"
        );
        std::thread::sleep(Duration::from_millis(20));
    }
    server.stop();
}

#[test]
fn server_rejects_late_work_under_reject_deadline() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut client = Client::connect(&server.addr).expect("client");

    // a 1 ms reject-late budget on a long generation: the deadline
    // expires before (or while) the batch runs, so the reply is a
    // deadline rejection, not a latent
    let req = Json::obj()
        .set("family", "image")
        .set("label", 1.0)
        .set("steps", 500usize)
        .set("policy", "no-cache")
        .set("deadline_ms", 1usize)
        .set("deadline_policy", "reject");
    let resp = client.call(&req).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false), "{resp:?}");
    assert_eq!(resp.get("deadline_missed").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
    let summary = client.metrics_summary().unwrap();
    assert!(summary.contains("dl_miss=1"), "{summary}");

    // a generous best-effort budget delivers the result unflagged
    let ok = client
        .call(
            &Json::obj()
                .set("family", "image")
                .set("label", 1.0)
                .set("steps", 4usize)
                .set("deadline_ms", 600_000usize),
        )
        .unwrap();
    assert_eq!(ok.get("ok").unwrap().as_bool(), Some(true), "{ok:?}");
    assert!(ok.get("deadline_missed").is_none(), "{ok:?}");
    server.stop();
}

#[test]
fn server_accepts_priority_field_and_rejects_unknown_classes() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut client = Client::connect(&server.addr).expect("client");

    // both classes round-trip; batch-class work completes normally when
    // no interactive traffic competes
    for class in ["interactive", "batch"] {
        let resp = client
            .call(
                &Json::obj()
                    .set("family", "image")
                    .set("label", 1.0)
                    .set("steps", 4usize)
                    .set("priority", class),
            )
            .unwrap();
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{class}: {resp:?}");
    }
    // an unknown class is a wire error, not a silent default
    let bad = client
        .call(
            &Json::obj()
                .set("family", "image")
                .set("label", 1.0)
                .set("priority", "urgent"),
        )
        .unwrap();
    assert_eq!(bad.get("ok").unwrap().as_bool(), Some(false), "{bad:?}");
    assert!(
        bad.get("error").and_then(|v| v.as_str()).unwrap_or("").contains("priority"),
        "{bad:?}"
    );
    server.stop();
}

#[test]
fn server_preempts_batch_class_and_cancelling_its_parked_session_frees_it() {
    // one replica, so a batch-class generation and interactive traffic
    // always contend for the same executor: the long batch job must be
    // preempted (parked) to let interactive work through, and cancelling
    // it while it bounces between parked and running must free the
    // parked lane and reconcile the counters
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(1);
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(5);
    let c = Arc::new(Coordinator::start(cfg).expect("coordinator"));
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");

    // batch-class long job on a streaming connection, in its own thread
    // (the streaming call blocks until the final outcome line)
    let (id_tx, id_rx) = std::sync::mpsc::channel();
    let addr = server.addr;
    let streamer = std::thread::spawn(move || {
        let mut streaming = Client::connect(&addr).expect("client");
        let req = Json::obj()
            .set("family", "image")
            .set("label", 1.0)
            .set("steps", 5000usize)
            .set("policy", "no-cache")
            .set("priority", "batch")
            .set("seed", 5u64);
        let mut sent = false;
        streaming
            .call_streaming(&req, |ev| {
                if !sent {
                    if let Some(id) = ev.get("id").and_then(|v| v.as_u64()) {
                        let _ = id_tx.send(id);
                        sent = true;
                    }
                }
            })
            .expect("streaming call")
    });
    let batch_id = id_rx
        .recv_timeout(Duration::from_secs(120))
        .expect("batch job never started");

    // interactive traffic until the batch job has demonstrably been
    // preempted at least once
    let t0 = Instant::now();
    while Metrics::get(&c.metrics().preemptions) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(120), "batch job was never preempted");
        let mut r = image_request(7, Policy::no_cache());
        r.steps = 2;
        r.priority = PriorityClass::Interactive;
        c.generate_blocking(r).expect("interactive request");
    }

    // cancel the batch job (parked or just resumed — both must work)
    let mut killer = Client::connect(&server.addr).expect("client");
    assert!(killer.cancel(batch_id).expect("cancel rpc"), "batch id must be known");
    let outcome = streamer.join().expect("streamer thread");
    assert_eq!(outcome.get("ok").unwrap().as_bool(), Some(false), "{outcome:?}");
    assert_eq!(outcome.get("cancelled").and_then(|v| v.as_bool()), Some(true), "{outcome:?}");

    // the parked lane is empty again (a cancelled parked session never
    // resumes), counters reconcile, and the stack still serves
    let t0 = Instant::now();
    while Metrics::get(&c.metrics().parked_sessions) != 0 || c.parked_len() != 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "cancelled batch job still parked: gauge={} queue={}",
            Metrics::get(&c.metrics().parked_sessions),
            c.parked_len()
        );
        std::thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(Metrics::get(&c.metrics().requests_cancelled), 1);
    assert!(Metrics::get(&c.metrics().preemptions) >= 1);
    let after = killer
        .call(&Json::obj().set("family", "image").set("label", 2.0).set("steps", 4usize))
        .unwrap();
    assert_eq!(after.get("ok").unwrap().as_bool(), Some(true), "{after:?}");
    server.stop();
}
