//! Observability subsystem integration (ISSUE 10, docs/adr/009):
//! disabled-mode no-allocation guarantee, concurrent writers into one
//! bounded trace sink, flight-recorder ring wraparound with pinned
//! retention, bitwise-identical generation output at every trace
//! level, a traced generate over the v2 mux whose timeline
//! reconstructs the queue-wait / calibration / per-step decomposition,
//! the `{"cmd":"dump"}` endpoint feeding `obs::export` (Chrome trace
//! JSON + text render), the structured `{"cmd":"metrics"}` JSON field
//! set, and trace-id tags on typed error replies.
//!
//! Every test that touches the process-global trace level or flight
//! recorder serializes through [`at_level`]; the rest of the suite can
//! run in parallel around them.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Duration;

use smoothcache::coordinator::{Coordinator, CoordinatorConfig, Policy, Request};
use smoothcache::model::Cond;
use smoothcache::obs::export::{chrome_trace, render, DumpEntry};
use smoothcache::obs::{
    self, recorder, BatchTrace, FlightEntry, FlightRecorder, Outcome, TraceHandle, TraceLevel,
    MAX_TRACE_EVENTS,
};
use smoothcache::server::{Client, Client2, Server};
use smoothcache::solvers::SolverKind;
use smoothcache::util::json::{parse, Json};

// ---------------------------------------------------------------------------
// Counting allocator: the disabled-mode test asserts the obs API makes
// zero heap allocations on this thread. Thread-local counting keeps
// parallel sibling tests from polluting the count; `try_with` tolerates
// allocation during TLS teardown.
// ---------------------------------------------------------------------------

thread_local! {
    static ALLOCS: Cell<usize> = const { Cell::new(0) };
}

struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

fn allocs_on_this_thread() -> usize {
    ALLOCS.with(|c| c.get())
}

// ---------------------------------------------------------------------------
// Level serialization: the trace level and the flight recorder are
// process-global, so every test that sets or reads them holds this
// gate and restores the previous level on drop.
// ---------------------------------------------------------------------------

static LEVEL_GATE: Mutex<()> = Mutex::new(());

struct LevelGuard {
    _gate: MutexGuard<'static, ()>,
    prev: TraceLevel,
}

fn at_level(l: TraceLevel) -> LevelGuard {
    let gate = LEVEL_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let prev = obs::level();
    obs::set_level(l);
    LevelGuard { _gate: gate, prev }
}

impl Drop for LevelGuard {
    fn drop(&mut self) {
        obs::set_level(self.prev);
    }
}

fn coord() -> Coordinator {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(10);
    cfg.calib_samples = 2;
    Coordinator::start(cfg).expect("coordinator")
}

fn gen_req(seed: u64) -> Json {
    Json::obj()
        .set("family", "image")
        .set("label", (seed % 10) as f64)
        .set("steps", 6usize)
        .set("solver", "ddim")
        .set("policy", "fora:2")
        .set("seed", seed)
}

fn event_names(trace: &Json) -> Vec<(String, Json)> {
    trace
        .get("events")
        .and_then(|v| v.as_arr())
        .expect("trace.events array")
        .iter()
        .map(|e| (e.get("name").and_then(|n| n.as_str()).unwrap_or("").to_string(), e.clone()))
        .collect()
}

// ---------------------------------------------------------------------------
// Disabled mode
// ---------------------------------------------------------------------------

/// At `TraceLevel::Off` the entire obs surface — opening handles,
/// events, spans, error tags, batch fan-out, fine scopes, site events,
/// snapshots, finish — performs zero heap allocations (docs/adr/009:
/// "off costs one atomic load").
#[test]
fn disabled_mode_allocates_nothing() {
    let _lvl = at_level(TraceLevel::Off);
    // warm every lazy path (TLS slots, level cache) before counting
    let warm = TraceHandle::start();
    warm.event("warm", 0, 0, 0, f64::NAN);
    obs::site_event(0, 0, true, None);
    let _ = allocs_on_this_thread();

    let before = allocs_on_this_thread();
    for i in 0..1000u64 {
        let h = TraceHandle::start();
        assert!(!h.is_active());
        assert_eq!(h.id(), 0);
        h.set_meta(i, "image/fora:2");
        h.event("submit", i, 0, 0, f64::NAN);
        let t0 = h.begin();
        h.span_from("step", t0, i, 0, 0, f64::NAN);
        assert!(h.err_tag().is_empty());
        assert!(h.snapshot().is_none());
        obs::site_event(i as usize, 0, i % 2 == 0, Some(0.25));
        let bt = BatchTrace::new([&h].into_iter());
        assert!(!bt.is_active());
        bt.event("batch", 1, 0, 0, f64::NAN);
        bt.span_from("calibrate", bt.begin(), 0, 0, 0, f64::NAN);
        let out = obs::with_fine_scope(&bt, || i * 2);
        assert_eq!(out, i * 2);
        h.finish(Outcome::Ok);
    }
    let after = allocs_on_this_thread();
    assert_eq!(
        after - before,
        0,
        "disabled tracing must not allocate ({} allocations in 1000 iterations)",
        after - before
    );
}

// ---------------------------------------------------------------------------
// Concurrent writers + bounded sink
// ---------------------------------------------------------------------------

/// Executor threads share one handle per request: hammer a single sink
/// from many threads, then check the buffer honored its bound, counted
/// every overflow, and `finish` deposited exactly one flight entry no
/// matter how many threads race it.
#[test]
fn concurrent_writers_bound_buffer_and_finish_once() {
    let _lvl = at_level(TraceLevel::Coarse);
    recorder().clear();

    let h = TraceHandle::start();
    assert!(h.is_active());
    let id = h.id();
    const THREADS: usize = 8;
    const PER_THREAD: usize = 2000;

    let workers: Vec<_> = (0..THREADS)
        .map(|t| {
            let h = h.clone();
            std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    h.event("step", t as u64, i as u64, 0, f64::NAN);
                }
                h.finish(Outcome::Failed);
            })
        })
        .collect();
    for w in workers {
        w.join().expect("writer thread");
    }

    let t = h.snapshot().expect("snapshot after finish");
    assert_eq!(t.events.len(), MAX_TRACE_EVENTS, "buffer bound violated");
    assert_eq!(
        t.dropped as usize,
        THREADS * PER_THREAD - MAX_TRACE_EVENTS,
        "every overflowed event must be counted"
    );
    let mine: Vec<_> = recorder().dump().into_iter().filter(|e| e.trace_id == id).collect();
    assert_eq!(mine.len(), 1, "racing finish() calls must deposit exactly one entry");
    assert_eq!(mine[0].outcome, "failed");
    assert!(mine[0].pinned, "failed outcomes are pinned");
}

/// Ring wraparound with pinned retention on a private recorder: ok
/// entries rotate through the ring while pinned (errored) entries
/// survive past wraparound in their own bounded FIFO lane.
#[test]
fn ring_wraparound_retains_pinned_entries() {
    let rec = FlightRecorder::with_capacity(4, 2);
    let entry = |id: u64, outcome: &'static str, pinned: bool| FlightEntry {
        trace_id: id,
        request_id: id,
        label: "image/fora:2".into(),
        outcome,
        pinned,
        dropped: 0,
        events: Vec::new(),
    };
    for id in 0..10 {
        rec.record(entry(id, "ok", false));
    }
    for id in 100..103 {
        rec.record(entry(id, "deadline", true));
    }
    for id in 10..20 {
        rec.record(entry(id, "ok", false));
    }
    let ids: Vec<u64> = rec.dump().iter().map(|e| e.trace_id).collect();
    // ring keeps the newest 4 ok entries; pinned lane keeps its newest
    // 2 regardless of how many ok entries wrapped past them
    assert_eq!(ids, vec![16, 17, 18, 19, 101, 102]);
    rec.clear();
    assert!(rec.is_empty());
}

// ---------------------------------------------------------------------------
// Instrumentation never changes results
// ---------------------------------------------------------------------------

/// The acceptance bar: the same request produces bitwise-identical
/// latents with tracing off, coarse, and fine — instrumentation
/// observes the pipeline, it never perturbs it.
#[test]
fn generation_bitwise_identical_across_trace_levels() {
    let _lvl = at_level(TraceLevel::Off);
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(1);
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(5);
    let coord = Coordinator::start(cfg).expect("coordinator");

    let run = |lvl: TraceLevel| -> Vec<u32> {
        obs::set_level(lvl);
        let req = Request {
            id: 0,
            family: "image".into(),
            cond: Cond::Label(vec![3]),
            solver: SolverKind::Ddim,
            steps: 6,
            cfg_scale: 1.0,
            seed: 42,
            policy: Policy::parse("fora:2").expect("policy"),
            compute: Default::default(),
            priority: Default::default(),
        };
        let resp = coord
            .submit(req)
            .recv_timeout(Duration::from_secs(120))
            .expect("answered")
            .expect("generation ok");
        resp.latent.data.iter().map(|v| v.to_bits()).collect()
    };

    let off = run(TraceLevel::Off);
    let coarse = run(TraceLevel::Coarse);
    let fine = run(TraceLevel::Fine);
    assert!(!off.is_empty());
    assert_eq!(off, coarse, "coarse tracing changed the generated latent");
    assert_eq!(off, fine, "fine tracing changed the generated latent");
    coord.shutdown();
}

// ---------------------------------------------------------------------------
// End-to-end timelines over the wire
// ---------------------------------------------------------------------------

/// A traced generate over the v2 mux returns a timeline whose spans
/// reconstruct the queue-wait / calibration / per-step-execute
/// decomposition: one `step` span per solver step, per-site decisions
/// at fine level, frame ingress/egress, and a queue-wait consistent
/// with the reply's own timing fields.
#[test]
fn traced_v2_generate_returns_decomposed_timeline() {
    let _lvl = at_level(TraceLevel::Fine);
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let v2 = Client2::connect(&server.addr).expect("client2");

    let steps = 6usize;
    let resp = v2.call(&gen_req(7).set("trace", true)).expect("traced call");
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
    let trace = resp.get("trace").expect("reply must carry the timeline").clone();
    assert!(trace.get("trace_id").and_then(|v| v.as_u64()).unwrap_or(0) > 0);

    let events = event_names(&trace);
    let count = |n: &str| events.iter().filter(|(name, _)| name == n).count();
    for required in
        ["submit", "queue_push", "queue_pop", "batch", "calibrate", "frame_in", "frame_out"]
    {
        assert!(count(required) >= 1, "timeline missing {required:?}: {events:?}");
    }
    // per-step execute decomposition: exactly one span per solver step
    assert_eq!(count("step"), steps, "one step span per solver step: {events:?}");
    // fine granularity: per-site reuse decisions, each tagged with a
    // valid step index and a compute/reuse bit
    let sites: Vec<&Json> =
        events.iter().filter(|(n, _)| n == "site").map(|(_, e)| e).collect();
    assert!(!sites.is_empty(), "fine level must record site events");
    for s in &sites {
        assert!(s.get("a").and_then(|v| v.as_usize()).unwrap() < steps);
        assert!(s.get("c").and_then(|v| v.as_u64()).unwrap() <= 1);
    }
    // frame ingress carries the payload size
    let frame_in = events.iter().find(|(n, _)| n == "frame_in").map(|(_, e)| e).unwrap();
    assert!(frame_in.get("a").and_then(|v| v.as_u64()).unwrap() > 0);
    // queue-wait span agrees with the reply's own queue_s field
    let qpop = events.iter().find(|(n, _)| n == "queue_pop").map(|(_, e)| e).unwrap();
    let qwait_s = qpop.get("f").and_then(|v| v.as_f64()).expect("queue_pop carries qwait");
    let queue_s = resp.get("queue_s").and_then(|v| v.as_f64()).unwrap();
    assert!(qwait_s >= 0.0);
    assert!(
        (qwait_s - queue_s).abs() < 0.5,
        "timeline qwait {qwait_s}s inconsistent with reply queue_s {queue_s}s"
    );
    // the step spans decompose the exec window: their total duration
    // cannot exceed the reply's end-to-end time
    let step_total_s: f64 = events
        .iter()
        .filter(|(n, _)| n == "step")
        .map(|(_, e)| e.get("dur_us").and_then(|v| v.as_f64()).unwrap_or(0.0) / 1e6)
        .sum();
    let total_s = resp.get("total_s").and_then(|v| v.as_f64()).unwrap();
    assert!(
        step_total_s <= total_s + 0.25,
        "step spans ({step_total_s}s) exceed end-to-end time ({total_s}s)"
    );
    // the decomposition is consistent with the metrics the same run fed
    let m = {
        let mut v1 = Client::connect(&server.addr).expect("v1 client");
        v1.metrics_json().expect("metrics json")
    };
    assert!(m.get("completed").and_then(|v| v.as_u64()).unwrap() >= 1);
    assert!(m.get("steps").and_then(|v| v.as_u64()).unwrap() >= steps as u64);

    server.stop();
}

/// `"trace":true` over the v1 line protocol returns the same timeline
/// shape (recv/send instead of frames), and the flight-recorder dump
/// endpoint feeds `obs::export`: Chrome trace JSON that parses, and a
/// non-empty text render.
#[test]
fn dump_endpoint_feeds_export() {
    let _lvl = at_level(TraceLevel::Coarse);
    recorder().clear();
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut v1 = Client::connect(&server.addr).expect("v1 client");

    let resp = v1.call(&gen_req(3).set("trace", true)).expect("traced v1 call");
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");
    let trace = resp.get("trace").expect("v1 reply must carry the timeline");
    let events = event_names(trace);
    for required in ["recv", "send", "submit", "queue_pop"] {
        assert!(
            events.iter().any(|(n, _)| n == required),
            "v1 timeline missing {required:?}: {events:?}"
        );
    }

    let dump = v1.dump().expect("dump");
    assert_eq!(dump.get("ok").and_then(|v| v.as_bool()), Some(true));
    assert_eq!(dump.get("level").and_then(|v| v.as_str()), Some("coarse"));
    let entries = DumpEntry::from_dump(&dump).expect("parse dump");
    assert!(!entries.is_empty(), "recorder must retain the completed request");

    // Chrome trace-event export round-trips through the crate's parser
    let chrome = chrome_trace(&entries).to_string();
    let back = parse(&chrome).expect("chrome trace must be valid JSON");
    let te = back.get("traceEvents").and_then(|v| v.as_arr()).expect("traceEvents");
    assert!(!te.is_empty());
    assert!(te.iter().any(|e| e.get("ph").and_then(|v| v.as_str()) == Some("X")));
    // text render names every retained trace
    let text = render(&entries);
    for e in &entries {
        assert!(text.contains(&e.trace_id.to_string()), "render missing trace {}", e.trace_id);
    }

    server.stop();
}

// ---------------------------------------------------------------------------
// Structured metrics + trace-id error tags
// ---------------------------------------------------------------------------

/// `{"cmd":"metrics","format":"json"}` pins the structured field set
/// (ISSUE 10 satellite 1): every summary key has a JSON mirror and the
/// object round-trips through the crate's own parser.
#[test]
fn metrics_json_pins_field_set() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut v1 = Client::connect(&server.addr).expect("v1 client");
    let resp = v1.call(&gen_req(5)).expect("generate");
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(true), "{resp:?}");

    let m = v1.metrics_json().expect("metrics json");
    for key in [
        "workers", "requests", "completed", "failed", "cancelled", "dl_miss", "rejected",
        "batches", "qdepth", "qpeak", "occupancy", "plan_hits", "plan_miss", "e2e_mean",
        "e2e_p95", "queue_mean", "qwait_mean", "qwait_p95", "exec_mean", "steps", "step_mean",
        "skips", "branch_total", "preempt", "resumes", "parked", "park_peak", "resume_mean",
        "e2e_int_p50", "e2e_int_p95", "e2e_int_p99", "e2e_bat_p50", "e2e_bat_p95",
        "e2e_bat_p99", "qwait_int_mean", "qwait_bat_mean", "v2_conns", "v2_credit_rej",
    ] {
        assert!(m.get(key).is_some(), "metrics JSON missing pinned key {key:?}");
    }
    assert!(m.get("completed").and_then(|v| v.as_u64()).unwrap() >= 1);
    assert!(m.get("requests").and_then(|v| v.as_u64()).unwrap() >= 1);
    // numbers stay numbers through a parse round-trip
    let back = parse(&m.to_string()).expect("round-trip");
    assert!(back.get("e2e_mean").and_then(|v| v.as_f64()).is_some());

    server.stop();
}

/// Typed error replies carry the trace id (ISSUE 10 satellite 2): a
/// reject-deadline miss answers `deadline: … [trace N]`, and N resolves
/// to a pinned flight-recorder entry with the matching outcome.
#[test]
fn error_replies_carry_trace_id() {
    let _lvl = at_level(TraceLevel::Coarse);
    recorder().clear();
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let mut v1 = Client::connect(&server.addr).expect("v1 client");

    // 1ms budget against a 10ms batching window: expires before (or
    // while) executing, so the reject policy answers a deadline error
    let resp = v1
        .call(&gen_req(9).set("deadline_ms", 1u64).set("deadline_policy", "reject"))
        .expect("call");
    assert_eq!(resp.get("ok").and_then(|v| v.as_bool()), Some(false), "{resp:?}");
    let err = resp.get("error").and_then(|v| v.as_str()).unwrap_or("").to_string();
    assert!(err.starts_with("deadline:"), "unexpected error class: {err:?}");
    assert!(err.contains(" [trace "), "error must carry the trace id: {err:?}");

    // the tag cross-references a pinned recorder entry
    let tag_id: u64 = err
        .rsplit("[trace ")
        .next()
        .and_then(|s| s.trim_end_matches(']').trim().parse().ok())
        .unwrap_or_else(|| panic!("unparseable trace tag in {err:?}"));
    let dump = v1.dump().expect("dump");
    let entries = DumpEntry::from_dump(&dump).expect("parse dump");
    let hit = entries
        .iter()
        .find(|e| e.trace_id == tag_id)
        .unwrap_or_else(|| panic!("trace {tag_id} not retained; got {entries:?}"));
    assert_eq!(hit.outcome, "deadline");
    assert!(hit.pinned, "deadline misses must be pinned past ring wraparound");

    server.stop();
}
