//! Cross-language golden test: the Rust engine's composed forward pass
//! (embed → branches via AOT executables → final) must reproduce the
//! JAX reference forward recorded by aot.py in artifacts/goldens/.
//!
//! This pins the entire stack: Pallas kernels → HLO text → PJRT load →
//! weight binding → branch composition → residual arithmetic.

use smoothcache::model::{Cond, Engine};
use smoothcache::tensor::Tensor;
use smoothcache::util::json::{parse, Json};

fn artifacts_ready() -> bool {
    smoothcache::artifacts_dir().join("manifest.json").exists()
}

fn load_golden(family: &str) -> Json {
    let p = smoothcache::artifacts_dir().join("goldens").join(format!("{family}.json"));
    parse(&std::fs::read_to_string(p).expect("golden file")).expect("golden json")
}

fn run_family_golden(family: &str) {
    if !artifacts_ready() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return;
    }
    let g = load_golden(family);
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine open");
    engine.load_family(family).expect("load family");
    let fm = engine.family_manifest(family).unwrap().clone();

    let x = Tensor::new(
        {
            let mut s = vec![1usize];
            s.extend(&fm.latent_shape);
            s
        },
        g.get("x").unwrap().as_f32_vec().unwrap(),
    );
    let t: Vec<f32> = g.get("t").unwrap().as_f32_vec().unwrap();
    let cond = if fm.num_classes > 0 {
        Cond::Label(
            g.get("label")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as i32)
                .collect(),
        )
    } else {
        Cond::Prompt(
            g.get("prompt_ids")
                .unwrap()
                .as_arr()
                .unwrap()
                .iter()
                .map(|v| v.as_f64().unwrap() as i32)
                .collect(),
        )
    };

    // Collect per-branch delta L1 norms while running the forward pass.
    let mut deltas: Vec<(String, f64)> = Vec::new();
    let eps = {
        let mut cb = |block: usize, br: &str, d: &Tensor| {
            deltas.push((format!("blocks.{block}.{br}"), d.l1()));
        };
        engine
            .forward(family, &x, &t, &cond, Some(&mut cb))
            .expect("forward")
    };

    // 1) final eps matches the jax reference elementwise.
    let want: Vec<f32> = g.get("eps").unwrap().as_f32_vec().unwrap();
    assert_eq!(eps.len(), want.len(), "eps length");
    let max_ref = want.iter().fold(0.0f32, |m, &v| m.max(v.abs())).max(1e-6);
    let mut max_err = 0.0f32;
    for (a, b) in eps.data.iter().zip(&want) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(
        max_err / max_ref < 1e-4,
        "{family}: eps rel Linf err {} (abs {max_err}, ref scale {max_ref})",
        max_err / max_ref
    );

    // 2) every branch delta's L1 matches the recorded value.
    let want_deltas = g.get("branch_delta_l1").unwrap().as_obj().unwrap();
    assert_eq!(deltas.len(), want_deltas.len(), "branch count");
    for (name, l1) in &deltas {
        let want_l1 = want_deltas
            .iter()
            .find(|(k, _)| k == name)
            .unwrap_or_else(|| panic!("{family}: golden missing {name}"))
            .1
            .as_f64()
            .unwrap();
        let rel = (l1 - want_l1).abs() / want_l1.max(1e-9);
        assert!(rel < 1e-3, "{family}/{name}: delta L1 {l1} vs {want_l1} (rel {rel})");
    }
}

#[test]
fn golden_image() {
    run_family_golden("image");
}

#[test]
fn golden_audio() {
    run_family_golden("audio");
}

#[test]
fn golden_video() {
    run_family_golden("video");
}
