//! Smoke-runs every bench target (ISSUE 6, satellite 1): each bench
//! source is also registered as a `[[bin]]` in Cargo.toml, so cargo
//! exposes a compile-time `CARGO_BIN_EXE_<name>` path here and we can
//! shell the real binary with `--smoke --json <tmp>` — no nested cargo
//! invocation. Each run must exit 0 and emit a report that parses,
//! carries the expected area, the required metric keys, and
//! `smoke=true` in its metadata.

use std::process::Command;
use std::sync::Mutex;

use smoothcache::util::bench::report::BenchReport;

// even at smoke scale the benches saturate the GEMM pool; running the
// eleven subprocesses one at a time keeps the suite's footprint sane
static BENCH_GATE: Mutex<()> = Mutex::new(());

fn run_smoke(exe: &str, name: &str, area: &str, required: &[&str]) {
    run_smoke_with(exe, name, &[], area, required);
}

fn run_smoke_with(exe: &str, name: &str, extra_args: &[&str], area: &str, required: &[&str]) {
    let _gate = BENCH_GATE.lock().unwrap_or_else(|e| e.into_inner());
    let json_path = std::env::temp_dir()
        .join(format!("smoothcache_smoke_{}_{name}.json", std::process::id()));
    let json_path = json_path.to_string_lossy().into_owned();
    let out = Command::new(exe)
        .args(["--smoke", "--json", &json_path])
        .args(extra_args)
        .output()
        .unwrap_or_else(|e| panic!("spawn {name}: {e}"));
    assert!(
        out.status.success(),
        "{name} --smoke failed (status {:?})\nstdout:\n{}\nstderr:\n{}",
        out.status.code(),
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr),
    );
    let rep = BenchReport::load(&json_path)
        .unwrap_or_else(|e| panic!("{name}: emitted JSON did not load: {e}"));
    let _ = std::fs::remove_file(&json_path);
    assert_eq!(rep.area, area, "{name}: wrong report area");
    assert_eq!(
        rep.meta.iter().find(|(k, _)| k == "smoke").map(|(_, v)| v.as_str()),
        Some("true"),
        "{name}: report must record smoke=true"
    );
    // every emitter records the run-environment block (BenchReport::run_meta)
    for key in ["run_threads", "run_kernel", "run_compute", "run_workers"] {
        assert!(
            rep.meta.iter().any(|(k, _)| k == key),
            "{name}: report must record {key} in its meta; present: {:?}",
            rep.meta.iter().map(|(k, _)| k.as_str()).collect::<Vec<_>>()
        );
    }
    for key in required {
        assert!(
            rep.get(key).is_some(),
            "{name}: metric {key:?} missing from report; present: {:?}",
            rep.metrics.iter().map(|m| m.name.as_str()).collect::<Vec<_>>()
        );
    }
}

#[test]
fn smoke_perf_engine() {
    run_smoke(
        env!("CARGO_BIN_EXE_perf_engine"),
        "perf_engine",
        "engine",
        &[
            "forward_b1_mean_us",
            "generate_nocache_mean_us",
            "generate_fora2_mean_us",
            "session_overhead_x",
            "sched_speedup_dense_vs_map_x",
            "json_scan/speedup_x",
            "threads_speedup_4t_v_1t_x",
            "compute:simd/ffn_speedup_x",
            "compute:f32/forward_b1_mean_us",
            "compute:f16/forward_b1_mean_us",
            "compute:bf16/forward_b1_mean_us",
            "compute:int8/forward_b1_mean_us",
            "compute:f16/ssim",
            "compute:bf16/ssim",
            "compute:int8/ssim",
            "queue_wait_mean_ms",
            "exec_mean_ms",
            "e2e_mean_ms",
            "obs:overhead_pct",
            "obs:overhead_fine_pct",
            "obs:disabled_ns_per_event",
        ],
    );
}

#[test]
fn smoke_e2e_serving() {
    run_smoke(
        env!("CARGO_BIN_EXE_e2e_serving"),
        "e2e_serving",
        "serving",
        &[
            "no-cache/throughput_rps",
            "no-cache/plan_hit_rate",
            "no-cache/step_mean_ms",
            "no-cache/speedup_vs_no_cache_x",
            "fora:2/throughput_rps",
            "fora:2/speedup_vs_no_cache_x",
            "smooth:0.25/skip_pct",
            "drift:0.35/qwait_mean_s",
        ],
    );
}

#[test]
fn smoke_e2e_serving_mux() {
    // the protocol-v2 multiplexing lane (ADR-008) reports its own area
    run_smoke_with(
        env!("CARGO_BIN_EXE_e2e_serving"),
        "e2e_serving_mux",
        &["--mux", "4", "--workers", "2"],
        "serving_mux",
        &[
            "mux_speedup_x",
            "v1_serial_wall_s",
            "v2_mux_wall_s",
            "v2_throughput_rps",
            "worst_stream_p99_ms",
            "served",
        ],
    );
}

#[test]
fn smoke_table1_image() {
    run_smoke(
        env!("CARGO_BIN_EXE_table1_image"),
        "table1_image",
        "table1_image",
        &[
            "no_cache/ffd",
            "no_cache/gmacs",
            "fora2/gmacs",
            "fora2/lpips",
            "ours_s50/skip_pct",
            "ours_s50/latency_s",
        ],
    );
}

#[test]
fn smoke_table2_video() {
    run_smoke(
        env!("CARGO_BIN_EXE_table2_video"),
        "table2_video",
        "table2_video",
        &["no_cache/vbench", "ours_s15/gmacs", "ours_s22/skip_pct", "ours_s15/ssim"],
    );
}

#[test]
fn smoke_table3_audio() {
    run_smoke(
        env!("CARGO_BIN_EXE_table3_audio"),
        "table3_audio",
        "table3_audio",
        &[
            "no_cache/audiocaps/fd",
            "no_cache/musiccaps/kl",
            "ours_s20/gmacs",
            "ours_s37/songdescriber/clap",
        ],
    );
}

#[test]
fn smoke_fig2_error_curves() {
    run_smoke(
        env!("CARGO_BIN_EXE_fig2_error_curves"),
        "fig2_error_curves",
        "fig2",
        &[
            "image/mean_ci_width",
            "image/calib_s",
            "audio/mean_ci_width",
            "video/mean_ci_width",
        ],
    );
}

#[test]
fn smoke_fig5_compute_composition() {
    run_smoke(
        env!("CARGO_BIN_EXE_fig5_compute_composition"),
        "fig5_compute_composition",
        "fig5",
        &["image/cacheable_fraction", "image/forward_gmacs"],
    );
}

#[test]
fn smoke_fig_qualitative() {
    run_smoke(
        env!("CARGO_BIN_EXE_fig_qualitative"),
        "fig_qualitative",
        "fig_qualitative",
        &["image/files_written", "audio/files_written", "video/files_written"],
    );
}

#[test]
fn smoke_ablation_calibration() {
    run_smoke(
        env!("CARGO_BIN_EXE_ablation_calibration"),
        "ablation_calibration",
        "ablation_calibration",
        &["n1/agreement_pct", "n2/agreement_pct", "n1/ci_width_attn", "n2/ci_width_ffn"],
    );
}

#[test]
fn smoke_ablation_grouping() {
    run_smoke(
        env!("CARGO_BIN_EXE_ablation_grouping"),
        "ablation_grouping",
        "ablation_grouping",
        &["a15/grouped/ffd", "a15/per_site/ffd", "a50/per_site/skip_pct", "a30/grouped/lpips"],
    );
}

#[test]
fn smoke_ablation_pareto() {
    run_smoke(
        env!("CARGO_BIN_EXE_ablation_pareto"),
        "ablation_pareto",
        "ablation_pareto",
        &["fora_n2/ffd", "fora_n3/gmacs", "ours_s35/gmacs", "ours_s50/latency_s"],
    );
}
