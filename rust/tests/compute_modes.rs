//! Reduced-precision `compute:` knob integration suite.
//!
//! The precision ladder (f16 / bf16 / int8 weight storage with f32
//! accumulation, docs/adr/006) is opt-in per request. This suite pins
//! the end-to-end contract for every builtin family:
//!
//! * reduced-mode trajectories are deterministic (same request → same
//!   bits) and actually differ from the f32 reference (the knob is not
//!   silently ignored),
//! * their outputs clear the `quality::precision_gate` SSIM floors the
//!   benches report against (f16 ≥ 0.99, bf16/int8 ≥ 0.95),
//! * the knob survives the full serving path (coordinator → executor →
//!   session scoping), and
//! * requests at different precisions never share a dynamic batch.

use smoothcache::cache::{CachePlan, PlanRef, Schedule};
use smoothcache::coordinator::{Coordinator, CoordinatorConfig, Policy, Request};
use smoothcache::model::{Cond, Engine, Manifest};
use smoothcache::pipeline::{generate, GenConfig};
use smoothcache::quality::precision_gate;
use smoothcache::solvers::SolverKind;
use smoothcache::tensor::{ComputeMode, Tensor};

fn offline_engine(family: &str) -> Engine {
    let mut e = Engine::open(std::path::PathBuf::from("/nonexistent-artifacts"))
        .expect("builtin engine");
    e.load_family(family).expect("load family");
    e
}

fn family_cond(fm: &smoothcache::model::FamilyManifest) -> Cond {
    if fm.num_classes > 0 {
        Cond::Label(vec![3])
    } else {
        Cond::Prompt((0..fm.cond_len).map(|i| (i * 11 % fm.vocab) as i32).collect())
    }
}

fn run_mode(
    engine: &Engine,
    family: &str,
    fm: &smoothcache::model::FamilyManifest,
    mode: ComputeMode,
) -> Tensor {
    let schedule = Schedule::no_cache(3, &fm.branch_types);
    let plan = CachePlan::from_grouped(&schedule, &fm.branch_sites()).unwrap();
    let cfg = GenConfig::new(family, SolverKind::Ddim, 3)
        .with_seed(11)
        .with_compute(mode);
    let cond = family_cond(fm);
    generate(engine, &cfg, &cond, PlanRef::Plan(&plan), None)
        .expect("generate")
        .latent
}

/// The per-mode SSIM floors the quality gate holds reduced outputs to
/// (the same floors `benches/perf_engine.rs` reports against).
pub const MODE_FLOORS: [(ComputeMode, f64); 3] = [
    (ComputeMode::F16, 0.99),
    (ComputeMode::Bf16, 0.95),
    (ComputeMode::Int8, 0.95),
];

#[test]
fn reduced_modes_are_deterministic_distinct_and_pass_the_gate() {
    for (name, fm) in &Manifest::builtin().families {
        let engine = offline_engine(name);
        let reference = run_mode(&engine, name, fm, ComputeMode::F32);
        // f32 through the knob is the identity path
        assert_eq!(
            reference,
            run_mode(&engine, name, fm, ComputeMode::F32),
            "{name}: f32 must be deterministic"
        );
        for (mode, floor) in MODE_FLOORS {
            let out = run_mode(&engine, name, fm, mode);
            let again = run_mode(&engine, name, fm, mode);
            assert_eq!(out, again, "{name}/{}: reduced mode must be deterministic", mode.name());
            assert_ne!(
                out.data,
                reference.data,
                "{name}/{}: reduced mode produced f32 bits — the knob was ignored",
                mode.name()
            );
            let gate = precision_gate(&reference, &out, floor)
                .expect("precision gate");
            assert!(
                gate.pass,
                "{name}/{}: ssim {} below the {floor} floor",
                mode.name(),
                gate.ssim
            );
        }
    }
}

#[test]
fn compute_scope_does_not_leak_between_sessions() {
    // a reduced-mode generation followed by a default one on the same
    // thread must leave no ambient mode behind (the session scopes each
    // step and restores on exit, even across the same engine)
    let engine = offline_engine("image");
    let fm = engine.family_manifest("image").expect("manifest").clone();
    let f32_before = run_mode(&engine, "image", &fm, ComputeMode::F32);
    let _int8 = run_mode(&engine, "image", &fm, ComputeMode::Int8);
    let f32_after = run_mode(&engine, "image", &fm, ComputeMode::F32);
    assert_eq!(f32_before, f32_after, "int8 session leaked its compute mode");
    assert_eq!(smoothcache::tensor::quant::compute_mode(), ComputeMode::F32);
}

#[test]
fn compute_knob_rides_the_full_serving_path() {
    // coordinator → queue → executor → GenSession: a reduced-precision
    // request served end to end differs from the f32 serving result for
    // the same seed, still clears the gate, and is itself reproducible
    let request = |compute: ComputeMode| Request {
        id: 0,
        family: "image".into(),
        cond: Cond::Label(vec![5]),
        solver: SolverKind::Ddim,
        steps: 3,
        cfg_scale: 1.0,
        seed: 0xC0FFEE,
        policy: Policy::no_cache(),
        compute,
        priority: Default::default(),
    };
    let cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(1);
    let coord = Coordinator::start(cfg).expect("coordinator");
    let f32_resp = coord.generate_blocking(request(ComputeMode::F32)).expect("f32 response");
    let f16_resp = coord.generate_blocking(request(ComputeMode::F16)).expect("f16 response");
    let f16_again = coord.generate_blocking(request(ComputeMode::F16)).expect("f16 repeat");
    coord.shutdown();
    assert_eq!(f16_resp.latent, f16_again.latent, "served f16 must be reproducible");
    assert_ne!(
        f32_resp.latent.data, f16_resp.latent.data,
        "served f16 must not silently run at f32"
    );
    let gate = precision_gate(&f32_resp.latent, &f16_resp.latent, 0.99).expect("gate");
    assert!(gate.pass, "served f16 ssim {} below 0.99", gate.ssim);
}

#[test]
fn batch_key_separates_compute_modes() {
    let req = |compute: ComputeMode| Request {
        id: 0,
        family: "image".into(),
        cond: Cond::Label(vec![1]),
        solver: SolverKind::Ddim,
        steps: 8,
        cfg_scale: 1.0,
        seed: 1,
        policy: Policy::no_cache(),
        compute,
        priority: Default::default(),
    };
    let keys: Vec<_> = [ComputeMode::F32, ComputeMode::F16, ComputeMode::Bf16, ComputeMode::Int8]
        .into_iter()
        .map(|m| req(m).batch_key())
        .collect();
    for i in 0..keys.len() {
        for j in 0..keys.len() {
            if i == j {
                assert_eq!(keys[i], keys[j]);
            } else {
                assert_ne!(keys[i], keys[j], "modes {i} and {j} must not co-batch");
            }
        }
    }
}
