//! Plan-parity suite (ISSUE 4): every policy in the registry resolves
//! to a [`CachePlan`] whose per-(step, site) decisions are identical to
//! the legacy representations (grouped [`Schedule`]s and stringly-keyed
//! per-site maps) for all three families × two solvers; the dynamic
//! `drift:*` policy is bitwise invariant to the GEMM thread count; and
//! docs/protocol.md's policy table is pinned to the registry so the
//! wire docs cannot drift from the parser.

use std::collections::BTreeMap;

use smoothcache::cache::{
    calibrate, delta_dit, parse_policy, registry, registry_markdown_rows, CalibrationConfig,
    Decision, PlanCtx, PlanRef, Schedule,
};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, GenConfig};
use smoothcache::solvers::SolverKind;
use smoothcache::tensor::gemm;

fn engine_with(family: &str) -> Engine {
    let mut e = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    e.load_family(family).expect("load");
    e
}

/// The legacy spelling of a resolved policy, for comparison.
enum Legacy {
    Grouped(Schedule),
    Map(BTreeMap<String, Vec<Decision>>),
}

#[test]
fn every_policy_resolves_identically_to_its_legacy_representation() {
    let steps = 6usize;
    let wires = [
        "no-cache",
        "fora:2",
        "fora:3",
        "alternate",
        "delta-dit:2",
        "smooth:0.3",
        "smooth-persite:0.3",
    ];
    for family in ["image", "audio", "video"] {
        let engine = engine_with(family);
        let fm = engine.family_manifest(family).unwrap().clone();
        let sites = fm.branch_sites();
        for solver in [SolverKind::Ddim, SolverKind::RectifiedFlow] {
            let cc = CalibrationConfig {
                steps,
                num_samples: 2,
                k_max: 2,
                ..CalibrationConfig::new(solver, steps)
            };
            let curves = calibrate(&engine, family, &cc).expect("calibrate");
            for wire in wires {
                let planner = parse_policy(wire).unwrap();
                let ctx = PlanCtx {
                    family: &fm,
                    solver,
                    steps,
                    curves: if planner.needs_curves() { Some(&curves) } else { None },
                };
                let plan = planner.plan(&ctx).expect(wire);
                plan.validate().expect(wire);
                plan.validate_for(&fm, steps).expect(wire);

                let legacy = match wire {
                    "no-cache" => Legacy::Grouped(Schedule::no_cache(steps, &fm.branch_types)),
                    "fora:2" => Legacy::Grouped(Schedule::fora(steps, &fm.branch_types, 2)),
                    "fora:3" => Legacy::Grouped(Schedule::fora(steps, &fm.branch_types, 3)),
                    "alternate" => {
                        Legacy::Grouped(Schedule::alternate(steps, &fm.branch_types))
                    }
                    "smooth:0.3" => {
                        Legacy::Grouped(curves.smoothcache_schedule(0.3, &fm.branch_types))
                    }
                    "smooth-persite:0.3" => Legacy::Map(curves.per_site_schedule(0.3)),
                    "delta-dit:2" => {
                        Legacy::Map(delta_dit(steps, fm.depth, &fm.branch_types, 2, 0.5))
                    }
                    other => panic!("unlisted wire {other}"),
                };
                for (s_idx, (block, bt)) in sites.iter().enumerate() {
                    for step in 0..steps {
                        let expected = match &legacy {
                            Legacy::Grouped(s) => s.decision(step, bt),
                            Legacy::Map(m) => m[&format!("{block}.{bt}")][step],
                        };
                        assert_eq!(
                            plan.decision(step, s_idx),
                            expected,
                            "{family}/{}/{wire} step {step} site {block}.{bt}",
                            solver.name()
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn dynamic_drift_policy_is_bitwise_invariant_to_thread_count() {
    let engine = engine_with("image");
    let cfg = GenConfig::new("image", SolverKind::Ddim, 8).with_seed(13);
    let cond = Cond::Label(vec![4]);
    // generous bound: once any drift is measured the site reuses until
    // the gap cap, so skips are guaranteed for the untrained model
    let generous = parse_policy("drift:1e9").unwrap();
    let sp = generous.dynamic().expect("drift is dynamic");
    let base = gemm::with_threads(1, || {
        generate(&engine, &cfg, &cond, PlanRef::Planner(sp), None)
    })
    .expect("serial generate");
    assert!(base.stats.branch_reuses > 0, "drift:1e9 must reuse");
    for nt in [2usize, 8] {
        let out = gemm::with_threads(nt, || {
            generate(&engine, &cfg, &cond, PlanRef::Planner(sp), None)
        })
        .expect("parallel generate");
        assert_eq!(base.latent.data, out.latent.data, "threads={nt}");
        assert_eq!(base.stats.branch_computes, out.stats.branch_computes, "threads={nt}");
        assert_eq!(base.stats.branch_reuses, out.stats.branch_reuses, "threads={nt}");
    }
    // a tight bound takes drift-dependent decisions — whatever they
    // are, they must not depend on the thread count either
    let tight = parse_policy("drift:0.25").unwrap();
    let tsp = tight.dynamic().unwrap();
    let b2 = gemm::with_threads(1, || {
        generate(&engine, &cfg, &cond, PlanRef::Planner(tsp), None)
    })
    .expect("serial generate");
    for nt in [2usize, 8] {
        let o2 = gemm::with_threads(nt, || {
            generate(&engine, &cfg, &cond, PlanRef::Planner(tsp), None)
        })
        .expect("parallel generate");
        assert_eq!(b2.latent.data, o2.latent.data, "threads={nt}");
        assert_eq!(b2.stats.branch_computes, o2.stats.branch_computes, "threads={nt}");
    }
}

#[test]
fn dynamic_drift_policy_bounds_reuse_gaps() {
    // with an unbounded drift tolerance the only compute trigger after
    // warmup is the gap cap: per site, computes at steps 0 and 1, then
    // one compute per (gap+1) window
    let engine = engine_with("image");
    let fm = engine.family_manifest("image").unwrap().clone();
    let n_sites = fm.depth * fm.branch_types.len();
    let steps = 10usize;
    let planner = parse_policy("drift:1e9:2").unwrap();
    let sp = planner.dynamic().unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(3);
    let out = generate(&engine, &cfg, &Cond::Label(vec![1]), PlanRef::Planner(sp), None)
        .expect("generate");
    // per site: compute at 0, 1; reuse 2,3 (gap cap 2); compute 4;
    // reuse 5,6; compute 7; reuse 8,9 → 4 computes / 6 reuses
    assert_eq!(out.stats.branch_computes, 4 * n_sites);
    assert_eq!(out.stats.branch_reuses, 6 * n_sites);
}

#[test]
fn protocol_doc_policy_table_matches_registry() {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../docs/protocol.md");
    let doc = std::fs::read_to_string(path).expect("docs/protocol.md must exist");
    assert_eq!(registry_markdown_rows().len(), registry().len());
    for row in registry_markdown_rows() {
        assert!(
            doc.contains(&row),
            "docs/protocol.md policy table is missing the registry row:\n  {row}\n\
             (regenerate the table from cache::plan::registry_markdown_rows)"
        );
    }
}
