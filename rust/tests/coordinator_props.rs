//! `util::propcheck` properties for the coordinator (ISSUE 2): under
//! randomized Poisson arrival traces and executor worker counts,
//! (a) every submitted request is answered exactly once,
//! (b) batches never mix `BatchKey`s (observable end-to-end: every
//!     response carries its own request's latent geometry and nothing
//!     fails; and directly at the batcher layer below), and
//! (c) deadline flushes fire — partial groups never strand.
//!
//! Plus the ISSUE 3 shared-work-queue scheduler contracts:
//! (d) a replica stuck in a long calibration does not delay batches a
//!     sibling could serve (no head-of-line blocking), and
//! (e) when the queue is full, admission control answers every
//!     rejected request with a well-formed `overloaded:` error — it
//!     never hangs or drops them.
//!
//! And the ISSUE 5 cancellation contracts:
//! (f) cancelling a *queued* request frees its admission slot
//!     immediately and it never reaches a replica,
//! (g) cancelling an *in-flight* request stops executor work at the
//!     next solver-step boundary — including while a sibling replica
//!     holds the `smooth:*` calibration lock — and
//! (h) counters always reconcile: every submission is answered exactly
//!     once as completed, cancelled, rejected or failed.
//!
//! And the ISSUE 8 preemptive-scheduling contracts (docs/adr/007):
//! (i) a batch-class generation preempted (parked) and resumed any
//!     number of times finishes **bitwise identical** to the same
//!     request served uninterrupted — for every registry policy,
//! (j) the class-aware queue conserves work under random interleavings
//!     (no request lost or served twice, admission accounting exact)
//!     and its count-based aging rule bounds how long lower-class work
//!     can starve — synthetic clock, no sleeps,
//! (k) a parked session survives a *sustained* interactive flood: it
//!     advances ≥ 1 step per aging-override resume and completes
//!     within `steps × (aging_limit + 1)` pops, and
//! (l) cancelling a *parked* session answers it immediately, drops it
//!     from the queue (it never resumes), and reconciles counters;
//!     plus the per-key calibration contract: a warm plan key is never
//!     blocked by a foreign key's in-flight calibration.

use std::time::{Duration, Instant};

use smoothcache::cache::plan::PlanCtx;
use smoothcache::cache::PlanRef;
use smoothcache::coordinator::{
    Batcher, BatcherConfig, Coordinator, CoordinatorConfig, InFlight, Lane, Metrics, ParkedSession,
    Policy, PriorityClass, Request, SubmitOpts, WorkItem, WorkQueue,
};
use smoothcache::model::{Cond, Engine, Manifest};
use smoothcache::pipeline::{GenConfig, GenSession};
use smoothcache::solvers::SolverKind;
use smoothcache::util::propcheck::{forall, gen};
use smoothcache::workload::PoissonTrace;

fn cond_for(family: &str, i: usize) -> Cond {
    if family == "image" {
        Cond::Label(vec![(i % 10) as i32])
    } else {
        Cond::Prompt(vec![(i % 256) as i32; 8])
    }
}

/// End-to-end property over the live coordinator: random worker counts,
/// Poisson-timed submissions, two families × two step counts (four
/// distinct `BatchKey`s in flight).
#[test]
fn prop_every_request_answered_exactly_once_any_worker_count() {
    let manifest = Manifest::builtin();
    forall(
        0xC0081,
        5,
        |r| {
            (
                gen::usize_in(r, 1, 4), // worker-pool size 1..=3
                gen::vec_of(r, 1, 9, |r| (r.below(2), r.below(2))),
            )
        },
        |case: &(usize, Vec<(usize, usize)>)| {
            let (workers, reqs) = case;
            let mut cfg =
                CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(*workers);
            cfg.max_wait = Duration::from_millis(5);
            let coord = Coordinator::start(cfg).map_err(|e| e.to_string())?;

            let trace =
                PoissonTrace::generate(300.0, reqs.len(), 10, 0, 0, 0xAC1D ^ *workers as u64);
            let t0 = Instant::now();
            let mut rxs = Vec::new();
            for (i, &(f, s)) in reqs.iter().enumerate() {
                let target = t0 + Duration::from_secs_f64(trace.items[i].arrival_s);
                if let Some(d) = target.checked_duration_since(Instant::now()) {
                    std::thread::sleep(d);
                }
                let family = ["image", "audio"][f];
                let req = Request {
                    id: 0,
                    family: family.into(),
                    cond: cond_for(family, i),
                    solver: SolverKind::Ddim,
                    steps: 2 + s,
                    cfg_scale: 1.0,
                    seed: i as u64,
                    policy: Policy::no_cache(),
                    compute: Default::default(),
                    priority: Default::default(),
                };
                rxs.push((family, coord.submit(req)));
            }

            for (family, rx) in &rxs {
                let resp = rx
                    .recv_timeout(Duration::from_secs(120))
                    .map_err(|_| "request never answered — deadline flush missing?".to_string())?
                    .map_err(|e| format!("request failed: {e}"))?;
                let fm = manifest.family(family).unwrap();
                let mut want = vec![1usize];
                want.extend(&fm.latent_shape);
                if resp.latent.shape != want {
                    return Err(format!(
                        "latent shape {:?} != {:?} for family {family} — batch mixed keys?",
                        resp.latent.shape, want
                    ));
                }
            }

            let m = coord.metrics();
            let n = reqs.len() as u64;
            if Metrics::get(&m.requests_submitted) != n {
                return Err(format!("submitted {} != {n}", Metrics::get(&m.requests_submitted)));
            }
            if Metrics::get(&m.requests_completed) != n {
                return Err(format!(
                    "completed {} != {n} (answered more or less than once)",
                    Metrics::get(&m.requests_completed)
                ));
            }
            if Metrics::get(&m.requests_failed) != 0 {
                return Err(format!("{} requests failed", Metrics::get(&m.requests_failed)));
            }
            coord.shutdown();
            // exactly once: the reply channels must now be disconnected
            // with no second message pending
            for (_, rx) in &rxs {
                match rx.try_recv() {
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {}
                    other => return Err(format!("reply channel not drained: {other:?}")),
                }
            }
            Ok(())
        },
    );
}

fn image_request(steps: usize, seed: u64, policy: Policy) -> Request {
    Request {
        id: 0,
        family: "image".into(),
        cond: Cond::Label(vec![(seed % 10) as i32]),
        solver: SolverKind::Ddim,
        steps,
        cfg_scale: 1.0,
        seed,
        policy,
        compute: Default::default(),
        priority: Default::default(),
    }
}

/// ISSUE 3 tentpole contract: with one replica held inside a long
/// calibration, warm (priority-lane) batches must be served by the
/// idle sibling *while the calibration is still running*. Under the
/// old round-robin per-replica channels roughly half of these batches
/// queued behind the calibrating replica and completed only after it
/// finished — exactly the head-of-line failure the shared pull queue
/// removes.
#[test]
fn stuck_calibration_does_not_delay_warm_batches_on_siblings() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(2);
    cfg.max_wait = Duration::from_millis(5);
    cfg.calib_samples = 8; // deliberately long: 8 samples × 16 steps
    let coord = Coordinator::start(cfg).expect("coordinator");

    // cold smooth key → normal lane → one replica calibrates (generous
    // alpha: any populated error cell below it yields reuse, so skips
    // are guaranteed without pinning the untrained model's error scale)
    let cold_rx = coord.submit(image_request(16, 1, Policy::smooth(2.0)));

    // wait until a replica is demonstrably inside the calibration
    let t0 = Instant::now();
    while Metrics::get(&coord.metrics().calibrations) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "calibration never started"
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    // warm traffic on the priority lane: both no-cache (no resolution at
    // all) AND fora:2 (a *resolving* calibration-free policy — it must
    // resolve without touching the store lock the calibration holds,
    // or the sibling would park on the mutex and the pool would be
    // head-of-line-blocked anyway)
    let warm_rxs: Vec<_> = (0..2)
        .map(|i| coord.submit(image_request(2, 10 + i, Policy::no_cache())))
        .chain((0..2).map(|i| coord.submit(image_request(2, 20 + i, Policy::fora(2)))))
        .collect();
    for rx in &warm_rxs {
        rx.recv_timeout(Duration::from_secs(120))
            .expect("warm request hung behind the calibrating replica")
            .expect("warm request failed");
    }
    // the sharp part: every warm response arrived while the cold
    // request was still in flight
    match cold_rx.try_recv() {
        Err(std::sync::mpsc::TryRecvError::Empty) => {}
        other => panic!(
            "cold request finished before the warm ones were all served: {other:?}"
        ),
    }
    let cold = cold_rx
        .recv_timeout(Duration::from_secs(300))
        .expect("cold request hung")
        .expect("cold request failed");
    assert!(cold.gen_stats.skip_fraction() > 0.0, "smooth α=2.0 should skip");

    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.calibrations), 1);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    assert_eq!(Metrics::get(&m.queue_rejections), 0);
    assert!(m.queue_wait.count() > 0, "executors must account queue wait");
    coord.shutdown();
}

/// ISSUE 3 admission-control contract: a burst far beyond
/// `--queue-depth` gets its overflow *rejected* with a well-formed
/// `overloaded:` error — rejected requests are answered immediately,
/// never hung, and the admitted ones still complete.
#[test]
fn queue_full_rejects_with_well_formed_overloaded_error() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir())
        .with_workers(1)
        .with_queue_depth(1);
    cfg.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(cfg).expect("coordinator");

    // 16 distinct step counts → 16 distinct BatchKeys → 16 batches
    // flushed nearly simultaneously into a depth-1 queue with a single
    // (busy) executor
    let rxs: Vec<_> = (0..16u64)
        .map(|i| coord.submit(image_request(2 + i as usize, i, Policy::no_cache())))
        .collect();

    let mut ok = 0u64;
    let mut rejected = 0u64;
    for rx in &rxs {
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(Ok(resp)) => {
                assert_eq!(resp.latent.shape, vec![1, 16, 16, 4]);
                ok += 1;
            }
            Ok(Err(e)) => {
                let msg = format!("{e}");
                assert!(
                    msg.starts_with("overloaded:"),
                    "rejection must carry the overloaded error shape, got {msg:?}"
                );
                rejected += 1;
            }
            Err(_) => panic!("request neither served nor rejected (hang)"),
        }
    }
    assert_eq!(ok + rejected, 16);
    assert!(rejected >= 1, "a 16-batch burst into a depth-1 queue must reject");
    assert!(ok >= 1, "admission control must not reject everything");

    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.queue_rejections), rejected);
    assert_eq!(Metrics::get(&m.requests_completed), ok);
    assert_eq!(Metrics::get(&m.requests_submitted), 16);
    coord.shutdown();
}

/// ISSUE 5 (f): a request cancelled while *queued* is answered with a
/// `cancelled:` error immediately, frees its admission slot (a request
/// the full queue just rejected is admitted right after), and never
/// reaches a replica.
#[test]
fn cancelling_a_queued_request_frees_its_admission_slot() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir())
        .with_workers(1)
        .with_queue_depth(1);
    cfg.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(cfg).expect("coordinator");

    // occupy the single executor with a long generation (distinct step
    // counts keep every request in its own batch)
    let (ptx, prx) = std::sync::mpsc::channel();
    let a = coord.submit_opts(
        image_request(800, 1, Policy::no_cache()),
        SubmitOpts { progress: Some(ptx), deadline: None, trace: Default::default() },
    );
    prx.recv_timeout(Duration::from_secs(120)).expect("executor never started A");

    // B fills the depth-1 queue…
    let b = coord.submit_opts(image_request(4, 2, Policy::no_cache()), SubmitOpts::default());
    let t0 = Instant::now();
    while coord.queue_len() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(60), "B never queued");
        std::thread::sleep(Duration::from_millis(2));
    }
    // …so C is rejected at admission
    let c = coord.submit_opts(image_request(5, 3, Policy::no_cache()), SubmitOpts::default());
    let c_err = c
        .reply
        .recv_timeout(Duration::from_secs(60))
        .expect("C must be answered")
        .expect_err("C must be rejected");
    assert!(format!("{c_err}").starts_with("overloaded:"), "{c_err}");

    // cancelling B answers it promptly and frees the slot *now* — no
    // waiting for the long batch A to finish
    assert!(coord.cancel(b.id), "B must be known while queued");
    let b_err = b
        .reply
        .recv_timeout(Duration::from_secs(5))
        .expect("cancelled queued request must be answered immediately")
        .expect_err("B must not have executed");
    assert!(format!("{b_err}").starts_with("cancelled:"), "{b_err}");
    assert_eq!(coord.queue_len(), 0, "cancelled request must free its slot");

    // the freed slot admits new work, which completes once A is gone
    let d = coord.submit_opts(image_request(6, 4, Policy::no_cache()), SubmitOpts::default());
    assert!(coord.cancel(a.id), "A must be known while executing");
    let a_err = a
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("cancelled in-flight request must be answered")
        .expect_err("A must have been aborted");
    assert!(format!("{a_err}").starts_with("cancelled:"), "{a_err}");
    let d_resp = d
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("D must be answered")
        .expect("D must complete");
    assert_eq!(d_resp.latent.shape, vec![1, 16, 16, 4]);

    // (h) counters reconcile: 4 submitted = 1 completed + 2 cancelled +
    // 1 rejected, nothing failed, nothing lost or double-answered
    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.requests_submitted), 4);
    assert_eq!(Metrics::get(&m.requests_completed), 1);
    assert_eq!(Metrics::get(&m.requests_cancelled), 2);
    assert_eq!(Metrics::get(&m.queue_rejections), 1);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    coord.shutdown();
    for rx in [&a.reply, &b.reply, &c.reply, &d.reply] {
        match rx.try_recv() {
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {}
            other => panic!("reply channel not drained exactly once: {other:?}"),
        }
    }
}

/// ISSUE 5 (g): cancelling an in-flight generation stops executor work
/// at the next solver-step boundary — pinned by watching per-step
/// progress events: after the cancel, only a bounded number of further
/// steps may execute (scheduling slack), not the remaining trajectory.
#[test]
fn cancelling_inflight_generation_stops_within_a_step() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(1);
    cfg.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(cfg).expect("coordinator");

    let steps = 600usize;
    let (ptx, prx) = std::sync::mpsc::channel();
    let ticket = coord.submit_opts(
        image_request(steps, 1, Policy::no_cache()),
        SubmitOpts { progress: Some(ptx), deadline: None, trace: Default::default() },
    );
    // first progress event ⇒ the generation is demonstrably in flight
    let first = prx.recv_timeout(Duration::from_secs(120)).expect("no progress event");
    assert_eq!(first.id, ticket.id);
    assert_eq!(first.steps, steps);
    assert!(coord.cancel(ticket.id));

    let err = ticket
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("cancelled request must be answered")
        .expect_err("cancelled request must not complete");
    assert!(format!("{err}").starts_with("cancelled:"), "{err}");

    // the executor checked between steps: the trajectory was abandoned
    // long before its 600 steps (progress events stop almost at once)
    let mut last_step = first.step;
    while let Ok(p) = prx.try_recv() {
        last_step = p.step;
    }
    assert!(
        last_step + 1 < steps / 2,
        "cancel was not prompt: reached step {last_step} of {steps}"
    );
    let m = coord.metrics();
    assert!(Metrics::get(&m.steps_executed) < (steps / 2) as u64);
    assert_eq!(Metrics::get(&m.requests_cancelled), 1);
    assert_eq!(Metrics::get(&m.requests_completed), 0);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    coord.shutdown();
}

/// ISSUE 5 (g), the sharp half: cancellation stays prompt and safe
/// while a *sibling replica* holds the `smooth:*` calibration lock —
/// the cancelled no-cache batch never touches the plan store, so the
/// in-flight calibration cannot delay the abort, and both requests'
/// counters reconcile afterwards.
#[test]
fn cancel_is_prompt_while_sibling_holds_calibration_lock() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(2);
    cfg.max_wait = Duration::from_millis(5);
    cfg.calib_samples = 8; // deliberately long calibration
    let coord = Coordinator::start(cfg).expect("coordinator");

    // cold smooth key → replica 1 enters calibration (and holds the
    // shared plan-store lock)
    let cold_rx = coord.submit(image_request(16, 1, Policy::smooth(2.0)));
    let t0 = Instant::now();
    while Metrics::get(&coord.metrics().calibrations) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(120), "calibration never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    // long no-cache request lands on the idle sibling…
    let (ptx, prx) = std::sync::mpsc::channel();
    let ticket = coord.submit_opts(
        image_request(600, 2, Policy::no_cache()),
        SubmitOpts { progress: Some(ptx), deadline: None, trace: Default::default() },
    );
    prx.recv_timeout(Duration::from_secs(120)).expect("sibling never started the long batch");
    // …and is cancelled mid-flight while the calibration still runs
    assert!(coord.cancel(ticket.id));
    let cancel_sent = Instant::now();
    let err = ticket
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("cancelled request must be answered despite the held calibration lock")
        .expect_err("cancelled request must not complete");
    assert!(format!("{err}").starts_with("cancelled:"), "{err}");
    let abort_latency = cancel_sent.elapsed();

    // the calibrating request is untouched: it completes and skips
    let cold = cold_rx
        .recv_timeout(Duration::from_secs(300))
        .expect("cold request hung")
        .expect("cold request failed");
    assert!(cold.gen_stats.skip_fraction() > 0.0);

    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.calibrations), 1);
    assert_eq!(Metrics::get(&m.requests_cancelled), 1);
    assert_eq!(Metrics::get(&m.requests_completed), 1);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    // promptness: far faster than the 600-step trajectory (whose steps
    // kept pace with the 16-step calibration batches on the sibling)
    assert!(
        abort_latency < Duration::from_secs(60),
        "abort took {abort_latency:?} — cancellation blocked behind the calibration?"
    );
    coord.shutdown();
}

// ─────────────────── ISSUE 8: preemptive scheduling ───────────────────

/// Wire spellings covering every registry policy (generous parameters
/// so smooth / drift actually skip on the untrained model; mirrors
/// `tests/session_parity.rs`).
fn registry_wires() -> [&'static str; 7] {
    [
        "no-cache",
        "fora:2",
        "alternate",
        "smooth:2.0",
        "smooth-persite:2.0",
        "delta-dit:2",
        "drift:1e9",
    ]
}

/// (i) Preemption parity, end to end on the live coordinator: for every
/// registry policy, a batch-class generation that gets preempted
/// (parked) and resumed under interactive traffic finishes **bitwise
/// identical** — latent and decision counters — to the same request on
/// a quiet coordinator, and every request is still answered exactly
/// once. (Cross-replica resume parity is pinned structurally by
/// `tests/session_parity.rs`, which resumes every snapshot on a fresh
/// engine instance.)
#[test]
fn preempted_batch_class_run_is_bitwise_identical_to_uninterrupted_run() {
    let steps = 32usize;
    for wire in registry_wires() {
        let policy = Policy::parse(wire).unwrap();
        let mut req = image_request(steps, 9, policy.clone());
        req.priority = PriorityClass::Batch;

        // quiet reference: same request, nothing to contend with
        let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(1);
        cfg.max_wait = Duration::from_millis(2);
        cfg.calib_samples = 2;
        let quiet = Coordinator::start(cfg).expect("coordinator");
        let reference = quiet.generate_blocking(req.clone()).expect(wire);
        quiet.shutdown();

        // contended run: one replica, so the batch-class job and the
        // interactive probes fight over the same executor
        let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(1);
        cfg.max_wait = Duration::from_millis(2);
        cfg.calib_samples = 2;
        let coord = Coordinator::start(cfg).expect("coordinator");
        let (ptx, prx) = std::sync::mpsc::channel();
        let ticket = coord.submit_opts(
            req,
            SubmitOpts { progress: Some(ptx), deadline: None, trace: Default::default() },
        );
        // first progress event ⇒ plan resolved (calibration done, for
        // smooth:*) and the trajectory demonstrably in flight
        prx.recv_timeout(Duration::from_secs(300))
            .unwrap_or_else(|_| panic!("{wire}: batch job never started"));

        // interactive probes, one at a time, until the batch job has
        // demonstrably been parked at a step boundary
        let mut probe_seed = 1000u64;
        let mut probes = 0u64;
        let mut early = None;
        let t0 = Instant::now();
        while Metrics::get(&coord.metrics().preemptions) == 0 {
            assert!(
                t0.elapsed() < Duration::from_secs(300),
                "{wire}: batch job was never preempted"
            );
            match ticket.reply.try_recv() {
                Ok(r) => {
                    early = Some(r);
                    break;
                }
                Err(std::sync::mpsc::TryRecvError::Empty) => {}
                Err(e) => panic!("{wire}: reply channel died: {e:?}"),
            }
            let rx = coord.submit(image_request(2, probe_seed, Policy::no_cache()));
            probe_seed += 1;
            probes += 1;
            rx.recv_timeout(Duration::from_secs(120))
                .expect("interactive probe hung")
                .expect("interactive probe failed");
        }
        let resp = match early {
            Some(r) => r.unwrap_or_else(|e| panic!("{wire}: batch job failed: {e}")),
            None => ticket
                .reply
                .recv_timeout(Duration::from_secs(300))
                .unwrap_or_else(|_| panic!("{wire}: batch job hung after preemption"))
                .unwrap_or_else(|e| panic!("{wire}: batch job failed: {e}")),
        };

        let m = coord.metrics();
        assert!(
            Metrics::get(&m.preemptions) >= 1,
            "{wire}: a {steps}-step batch-class job finished before a 2-step probe contended"
        );
        assert!(
            Metrics::get(&m.session_resumes) >= 1,
            "{wire}: a preempted job can only have finished via a resume"
        );
        // the sharp pin: parked + resumed ≡ uninterrupted, bitwise
        assert_eq!(
            resp.latent.data, reference.latent.data,
            "{wire}: preempted trajectory diverged from the uninterrupted run"
        );
        assert_eq!(resp.gen_stats.branch_computes, reference.gen_stats.branch_computes, "{wire}");
        assert_eq!(resp.gen_stats.branch_reuses, reference.gen_stats.branch_reuses, "{wire}");
        assert_eq!(resp.steps_completed, steps, "{wire}");
        // exactly once, nothing lost: the job + every probe completed
        assert_eq!(Metrics::get(&m.requests_submitted), probes + 1);
        assert_eq!(Metrics::get(&m.requests_completed), probes + 1);
        assert_eq!(Metrics::get(&m.requests_failed), 0);
        assert_eq!(Metrics::get(&m.requests_cancelled), 0);
        assert_eq!(Metrics::get(&m.parked_sessions), 0, "{wire}: nothing may stay parked");
        coord.shutdown();
        match ticket.reply.try_recv() {
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {}
            other => panic!("{wire}: batch job answered more than once: {other:?}"),
        }
    }
}

/// Build one real (tiny) [`smoothcache::pipeline::SessionState`] the
/// queue-level props clone into synthetic parked sessions — the queue
/// never looks inside it, but carrying a genuine snapshot keeps the
/// types honest.
fn tiny_snapshot() -> smoothcache::pipeline::SessionState {
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let policy = Policy::no_cache();
    let plan = policy
        .planner()
        .plan(&PlanCtx {
            family: engine.family_manifest("image").unwrap(),
            solver: SolverKind::Ddim,
            steps: 2,
            curves: None,
        })
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, 2).with_seed(1);
    let cond = Cond::Label(vec![0]);
    let mut s = GenSession::new(&engine, &cfg, &cond, PlanRef::Plan(&plan)).unwrap();
    s.step().unwrap();
    s.snapshot()
}

/// An [`InFlight`] whose reply channel is intentionally leaked (the
/// queue-level props never answer it).
fn queued_item(id: u64, class: PriorityClass) -> InFlight {
    let (tx, rx) = std::sync::mpsc::channel();
    std::mem::forget(rx);
    InFlight::new(
        Request {
            id,
            family: "image".into(),
            cond: Cond::Label(vec![1]),
            solver: SolverKind::Ddim,
            steps: 4,
            cfg_scale: 1.0,
            seed: id,
            policy: Policy::no_cache(),
            compute: Default::default(),
            priority: class,
        },
        tx,
    )
}

fn parked_of(state: &smoothcache::pipeline::SessionState, ids: &[u64]) -> ParkedSession {
    ParkedSession {
        members: ids
            .iter()
            .enumerate()
            .map(|(row, &id)| (row, queued_item(id, PriorityClass::Batch)))
            .collect(),
        state: state.clone(),
        target: ids.len().max(1),
        class: PriorityClass::Batch,
        exec_seconds: 0.0,
        first_exec: Instant::now(),
        parked_at: Instant::now(),
    }
}

/// Independent oracle of the queue's documented pick order and
/// admission rule, mirrored over plain `VecDeque`s of id lists.
#[derive(Default)]
struct QueueModel {
    ip: std::collections::VecDeque<Vec<u64>>,
    inorm: std::collections::VecDeque<Vec<u64>>,
    bp: std::collections::VecDeque<Vec<u64>>,
    bnorm: std::collections::VecDeque<Vec<u64>>,
    parked: std::collections::VecDeque<Vec<u64>>,
    queued: usize,
    high: usize,
}

impl QueueModel {
    fn admits(&self, n: usize, depth: usize) -> bool {
        self.queued == 0 || self.queued + n <= depth
    }

    fn has_work(&self) -> bool {
        self.queued > 0 || !self.parked.is_empty()
    }

    /// Mirror of `WorkQueue::pop` for a non-empty model: returns
    /// `(was_parked, member ids)`.
    fn pop(&mut self, aging_limit: usize) -> (bool, Vec<u64>) {
        let low_waiting =
            !self.bp.is_empty() || !self.bnorm.is_empty() || !self.parked.is_empty();
        if low_waiting && self.high >= aging_limit {
            self.high = 0;
            if let Some(v) = self.parked.pop_front() {
                return (true, v);
            }
            if let Some(v) = self.bp.pop_front().or_else(|| self.bnorm.pop_front()) {
                self.queued -= v.len();
                return (false, v);
            }
        }
        if let Some(v) = self.ip.pop_front().or_else(|| self.inorm.pop_front()) {
            self.high = if low_waiting { self.high + 1 } else { 0 };
            self.queued -= v.len();
            return (false, v);
        }
        if let Some(v) = self.parked.pop_front() {
            self.high = 0;
            return (true, v);
        }
        let v = self
            .bp
            .pop_front()
            .or_else(|| self.bnorm.pop_front())
            .expect("model_pop called on an empty model");
        self.high = 0;
        self.queued -= v.len();
        (false, v)
    }
}

fn fresh_ids(q: &smoothcache::coordinator::QueuedBatch) -> Vec<u64> {
    q.batch.iter().map(|it| it.request.id).collect()
}

fn parked_ids(ps: &ParkedSession) -> Vec<u64> {
    ps.members.iter().map(|(_, it)| it.request.id).collect()
}

/// (j) Synthetic-clock queue property (no sleeps, no executors): under
/// random interleavings of class/lane pushes, parked re-entries and
/// pops, the real queue agrees with the independent pick-order oracle
/// on every single decision — admission verdicts, serve order, aging
/// overrides — and conserves work exactly: every admitted id comes back
/// exactly once, fresh-slot accounting matches at every step, and a
/// close() drain surfaces everything that was still queued.
#[test]
fn prop_queue_matches_pick_order_oracle_under_random_interleavings() {
    let state = tiny_snapshot();
    forall(
        0xA61A68,
        60,
        |r| {
            (
                gen::usize_in(r, 1, 6),  // aging limit 1..=5
                gen::usize_in(r, 2, 10), // admission depth 2..=9
                gen::vec_of(r, 1, 40, |r| (r.below(4), r.below(4))),
            )
        },
        |case: &(usize, usize, Vec<(usize, usize)>)| {
            let (aging_limit, depth, ops) = case;
            let q = WorkQueue::with_aging(*depth, *aging_limit);
            let mut model = QueueModel::default();
            let mut next_id = 1u64;
            let mut mk_ids = |n: usize| -> Vec<u64> {
                let ids: Vec<u64> = (next_id..next_id + n as u64).collect();
                next_id += n as u64;
                ids
            };
            let check_pop = |model: &mut QueueModel| -> Result<(), String> {
                let (want_parked, want_ids) = model.pop(*aging_limit);
                match q.pop().ok_or("queue empty while model has work")? {
                    WorkItem::Fresh(b) => {
                        if want_parked {
                            return Err(format!(
                                "oracle expected parked {want_ids:?}, queue served fresh {:?}",
                                fresh_ids(&b)
                            ));
                        }
                        if fresh_ids(&b) != want_ids {
                            return Err(format!(
                                "serve order diverged: oracle {want_ids:?}, queue {:?}",
                                fresh_ids(&b)
                            ));
                        }
                    }
                    WorkItem::Parked(ps) => {
                        if !want_parked {
                            return Err(format!(
                                "oracle expected fresh {want_ids:?}, queue resumed {:?}",
                                parked_ids(&ps)
                            ));
                        }
                        if parked_ids(&ps) != want_ids {
                            return Err(format!(
                                "resume order diverged: oracle {want_ids:?}, queue {:?}",
                                parked_ids(&ps)
                            ));
                        }
                    }
                }
                Ok(())
            };
            for &(kind, arg) in ops {
                match kind {
                    // fresh push: class from kind, lane + size from arg
                    0 | 1 => {
                        let class = if kind == 0 {
                            PriorityClass::Interactive
                        } else {
                            PriorityClass::Batch
                        };
                        let lane = if arg % 2 == 0 { Lane::Priority } else { Lane::Normal };
                        let n = 1 + arg / 2; // 1..=2 requests
                        let ids = mk_ids(n);
                        let batch: Vec<InFlight> =
                            ids.iter().map(|&id| queued_item(id, class)).collect();
                        let admitted = q.push(batch, lane).is_ok();
                        if admitted != model.admits(n, *depth) {
                            return Err(format!(
                                "admission diverged for {ids:?}: queue {admitted}, oracle {}",
                                model.admits(n, *depth)
                            ));
                        }
                        if admitted {
                            model.queued += n;
                            let target = match (class, lane) {
                                (PriorityClass::Interactive, Lane::Priority) => &mut model.ip,
                                (PriorityClass::Interactive, Lane::Normal) => &mut model.inorm,
                                (PriorityClass::Batch, Lane::Priority) => &mut model.bp,
                                (PriorityClass::Batch, Lane::Normal) => &mut model.bnorm,
                            };
                            target.push_back(ids);
                        }
                    }
                    // parked re-entry: never admission-checked
                    2 => {
                        let ids = mk_ids(1 + arg % 2);
                        q.push_parked(parked_of(&state, &ids));
                        model.parked.push_back(ids);
                    }
                    // pop (skipped while empty — pop would block)
                    _ => {
                        if model.has_work() {
                            check_pop(&mut model)?;
                        }
                    }
                }
                if q.len() != model.queued {
                    return Err(format!(
                        "fresh-slot accounting diverged: queue {} vs oracle {}",
                        q.len(),
                        model.queued
                    ));
                }
                if q.parked_len() != model.parked.len() {
                    return Err(format!(
                        "parked accounting diverged: queue {} vs oracle {}",
                        q.parked_len(),
                        model.parked.len()
                    ));
                }
            }
            // graceful drain: everything still queued comes out, in
            // oracle order, then the queue signals exit
            q.close();
            while model.has_work() {
                check_pop(&mut model)?;
            }
            if q.pop().is_some() {
                return Err("queue still had work after the oracle drained".into());
            }
            Ok(())
        },
    );
}

/// (k) Starvation bound, synthetic clock: under a *sustained*
/// interactive flood (fresh interactive work is waiting before every
/// single pop), a parked session is still scheduled once per
/// `aging_limit + 1` pops — so a job with `n` steps left finishes
/// within `n × (aging_limit + 1)` pops, because the executor's
/// preempt-after-step rule guarantees ≥ 1 step of progress per resume.
#[test]
fn prop_no_parked_session_starves_under_sustained_interactive_flood() {
    let state = tiny_snapshot();
    forall(
        0x57A12E,
        30,
        |r| (gen::usize_in(r, 1, 6), gen::usize_in(r, 1, 21)),
        |case: &(usize, usize)| {
            let (aging_limit, steps_left) = *case;
            let q = WorkQueue::with_aging(1024, aging_limit);
            q.push_parked(parked_of(&state, &[1]));
            let mut remaining = steps_left;
            let mut pops = 0usize;
            let mut flood_id = 100u64;
            let bound = steps_left * (aging_limit + 1);
            while remaining > 0 {
                // keep the flood sustained: interactive work must be
                // waiting at every pop, or the bound does not apply
                while q.len() < 2 {
                    q.push(vec![queued_item(flood_id, PriorityClass::Interactive)], Lane::Priority)
                        .map_err(|_| "flood push rejected".to_string())?;
                    flood_id += 1;
                }
                pops += 1;
                if pops > bound {
                    return Err(format!(
                        "parked session starved: {remaining}/{steps_left} steps left \
                         after {pops} pops (bound {bound}, aging_limit {aging_limit})"
                    ));
                }
                match q.pop().ok_or("queue unexpectedly closed")? {
                    WorkItem::Fresh(b) => {
                        if b.class() != PriorityClass::Interactive {
                            return Err("flood lane served a non-interactive batch".into());
                        }
                    }
                    WorkItem::Parked(ps) => {
                        // executor contract: ≥ 1 step per scheduling slot
                        // (the preempt check runs *after* a step)
                        remaining -= 1;
                        if remaining > 0 {
                            q.push_parked(ps);
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// (l) Cancelling a *parked* session: answered immediately (while the
/// only executor is busy with interactive work), dropped from the
/// parked lane on the spot, never resumed afterwards, and the counters
/// reconcile — nothing lost, nothing double-answered.
#[test]
fn cancelling_a_parked_session_answers_it_and_it_never_resumes() {
    use std::sync::atomic::{AtomicBool, Ordering};

    // aging effectively off: while the flood below is waiting, the
    // parked session stays parked instead of bouncing
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir())
        .with_workers(1)
        .with_aging_limit(1_000_000);
    cfg.max_wait = Duration::from_millis(2);
    let coord = std::sync::Arc::new(Coordinator::start(cfg).expect("coordinator"));

    // the victim: a long batch-class job, watched via progress events
    let (ptx, prx) = std::sync::mpsc::channel();
    let mut req = image_request(400, 5, Policy::no_cache());
    req.priority = PriorityClass::Batch;
    let opts = SubmitOpts { progress: Some(ptx), deadline: None, trace: Default::default() };
    let ticket = coord.submit_opts(req, opts);
    prx.recv_timeout(Duration::from_secs(120)).expect("batch job never started");

    // interactive flood from a side thread (a small window of
    // outstanding requests keeps the queue non-empty)
    let stop = std::sync::Arc::new(AtomicBool::new(false));
    let flood = {
        let coord = std::sync::Arc::clone(&coord);
        let stop = std::sync::Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut outstanding = std::collections::VecDeque::new();
            let mut seed = 100u64;
            while !stop.load(Ordering::Relaxed) {
                while outstanding.len() < 3 {
                    outstanding.push_back(coord.submit(image_request(2, seed, Policy::no_cache())));
                    seed += 1;
                }
                let rx = outstanding.pop_front().unwrap();
                let _ = rx.recv_timeout(Duration::from_secs(120));
            }
            for rx in outstanding {
                let _ = rx.recv_timeout(Duration::from_secs(120));
            }
        })
    };

    // wait until the job is demonstrably parked, then cancel it
    let t0 = Instant::now();
    while coord.parked_len() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(120), "batch job never parked");
        std::thread::sleep(Duration::from_millis(2));
    }
    assert!(coord.cancel(ticket.id), "parked job must be cancellable by id");
    let err = ticket
        .reply
        .recv_timeout(Duration::from_secs(60))
        .expect("cancelled parked session must be answered while the executor is busy")
        .expect_err("cancelled parked session must not complete");
    assert!(format!("{err}").starts_with("cancelled:"), "{err}");

    // gone from the parked lane, and it never comes back: further
    // traffic is served without a single additional resume
    assert_eq!(coord.parked_len(), 0, "cancelled parked session must be dropped");
    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.parked_sessions), 0);
    let resumes_after = Metrics::get(&m.session_resumes);
    coord
        .generate_blocking(image_request(2, 999, Policy::no_cache()))
        .expect("pool must stay live after a parked cancel");
    assert_eq!(
        Metrics::get(&m.session_resumes),
        resumes_after,
        "a cancelled parked session must never resume"
    );

    stop.store(true, Ordering::Relaxed);
    flood.join().expect("flood thread");
    // reconcile: exactly one cancel, everything else completed
    assert_eq!(Metrics::get(&m.requests_cancelled), 1);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    assert_eq!(
        Metrics::get(&m.requests_completed) + 1,
        Metrics::get(&m.requests_submitted)
    );
    match ticket.reply.try_recv() {
        Err(std::sync::mpsc::TryRecvError::Empty | std::sync::mpsc::TryRecvError::Disconnected) => {}
        other => panic!("cancelled job answered twice: {other:?}"),
    }
}

/// ADR-002 residual, fixed this PR (per-key calibration slots): a
/// request for an **already-calibrated** key must never queue behind a
/// *different* key's in-flight calibration. Under the old store-wide
/// lock, the warm request below parked on the mutex K2's calibration
/// held; with per-key `CurveSlot`s it resolves from the plan cache and
/// completes while K2 is still calibrating. (Name referenced by the
/// `plan_shared` docs in `src/coordinator/executor.rs`.)
#[test]
fn warm_key_resolves_while_foreign_calibration_is_in_flight() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(2);
    cfg.max_wait = Duration::from_millis(5);
    cfg.calib_samples = 8; // K2's calibration is deliberately long
    let coord = Coordinator::start(cfg).expect("coordinator");
    let m = coord.metrics();

    // warm key K1 = (image, ddim, 4 steps) end to end
    coord
        .generate_blocking(image_request(4, 1, Policy::smooth(2.0)))
        .expect("warming K1 failed");
    assert_eq!(Metrics::get(&m.calibrations), 1);

    // cold key K2 = (image, ddim, 16 steps): one replica calibrates it
    let cold_rx = coord.submit(image_request(16, 2, Policy::smooth(2.0)));
    let t0 = Instant::now();
    while Metrics::get(&m.calibrations) < 2 {
        assert!(t0.elapsed() < Duration::from_secs(120), "K2 calibration never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    // the pin: a K1 request completes while K2 is still calibrating
    let warm = coord
        .generate_blocking(image_request(4, 3, Policy::smooth(2.0)))
        .expect("warm K1 request failed behind a foreign calibration");
    assert!(warm.gen_stats.skip_fraction() > 0.0, "smooth α=2.0 should skip");
    match cold_rx.try_recv() {
        Err(std::sync::mpsc::TryRecvError::Empty) => {}
        other => panic!("K2 finished before the warm K1 request was served: {other:?}"),
    }

    cold_rx
        .recv_timeout(Duration::from_secs(300))
        .expect("K2 hung")
        .expect("K2 failed");
    assert_eq!(Metrics::get(&m.calibrations), 2, "exactly one calibration per key");
    assert!(Metrics::get(&m.plan_cache_hits) >= 1, "warm K1 must hit the plan cache");
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    coord.shutdown();
}

/// Batcher-layer property with synthetic clocks (no sleeping): under
/// Poisson inter-arrival offsets, every request flushes by
/// `last_arrival + max_wait`, every flushed batch is homogeneous in
/// `BatchKey`, and no batch exceeds the effective max size.
#[test]
fn prop_deadline_flushes_fire_under_poisson_arrivals() {
    forall(
        0xF1054,
        40,
        |r| gen::vec_of(r, 1, 30, |r| (r.below(3), r.below(2))),
        |seq: &Vec<(usize, usize)>| {
            let max_wait = Duration::from_millis(50);
            let config = BatcherConfig {
                supported_batches: vec![1, 2, 4, 8],
                max_wait,
            };
            let mut batcher = Batcher::new(config);
            let trace = PoissonTrace::generate(100.0, seq.len(), 10, 0, 0, seq.len() as u64);
            let t0 = Instant::now();
            let families = ["image", "audio", "video"];
            let mut keep_rx = Vec::new(); // keep reply receivers alive
            let mut flushed = 0usize;
            let check_batches = |batches: Vec<Vec<InFlight>>| -> Result<usize, String> {
                let mut count = 0;
                for batch in batches {
                    let key = batch[0].request.batch_key();
                    if batch.len() > 8 {
                        return Err(format!("batch of {} exceeds max", batch.len()));
                    }
                    for it in &batch {
                        if it.request.batch_key() != key {
                            return Err("batch mixes BatchKeys".into());
                        }
                    }
                    count += batch.len();
                }
                Ok(count)
            };
            let mut last = t0;
            for (i, &(f, s)) in seq.iter().enumerate() {
                let now = t0 + Duration::from_secs_f64(trace.items[i].arrival_s);
                last = now;
                let (tx, rx) = std::sync::mpsc::channel();
                keep_rx.push(rx);
                let item = InFlight::new(
                    Request {
                        id: i as u64,
                        family: families[f].into(),
                        cond: cond_for(families[f], i),
                        solver: SolverKind::Ddim,
                        steps: 10 + s,
                        cfg_scale: 1.0,
                        seed: i as u64,
                        policy: Policy::no_cache(),
                        compute: Default::default(),
                        priority: Default::default(),
                    },
                    tx,
                );
                if let Some(batch) = batcher.push(item, now) {
                    flushed += check_batches(vec![batch])?;
                }
                flushed += check_batches(batcher.poll(now))?;
            }
            // one deadline sweep after the last arrival must drain all
            flushed += check_batches(batcher.poll(last + max_wait))?;
            if batcher.pending() != 0 {
                return Err(format!(
                    "{} requests stranded past the flush deadline",
                    batcher.pending()
                ));
            }
            if flushed != seq.len() {
                return Err(format!("flushed {flushed} != submitted {}", seq.len()));
            }
            Ok(())
        },
    );
}
