//! `util::propcheck` properties for the coordinator (ISSUE 2): under
//! randomized Poisson arrival traces and executor worker counts,
//! (a) every submitted request is answered exactly once,
//! (b) batches never mix `BatchKey`s (observable end-to-end: every
//!     response carries its own request's latent geometry and nothing
//!     fails; and directly at the batcher layer below), and
//! (c) deadline flushes fire — partial groups never strand.
//!
//! Plus the ISSUE 3 shared-work-queue scheduler contracts:
//! (d) a replica stuck in a long calibration does not delay batches a
//!     sibling could serve (no head-of-line blocking), and
//! (e) when the queue is full, admission control answers every
//!     rejected request with a well-formed `overloaded:` error — it
//!     never hangs or drops them.
//!
//! And the ISSUE 5 cancellation contracts:
//! (f) cancelling a *queued* request frees its admission slot
//!     immediately and it never reaches a replica,
//! (g) cancelling an *in-flight* request stops executor work at the
//!     next solver-step boundary — including while a sibling replica
//!     holds the `smooth:*` calibration lock — and
//! (h) counters always reconcile: every submission is answered exactly
//!     once as completed, cancelled, rejected or failed.

use std::time::{Duration, Instant};

use smoothcache::coordinator::{
    Batcher, BatcherConfig, Coordinator, CoordinatorConfig, InFlight, Metrics, Policy, Request,
    SubmitOpts,
};
use smoothcache::model::{Cond, Manifest};
use smoothcache::solvers::SolverKind;
use smoothcache::util::propcheck::{forall, gen};
use smoothcache::workload::PoissonTrace;

fn cond_for(family: &str, i: usize) -> Cond {
    if family == "image" {
        Cond::Label(vec![(i % 10) as i32])
    } else {
        Cond::Prompt(vec![(i % 256) as i32; 8])
    }
}

/// End-to-end property over the live coordinator: random worker counts,
/// Poisson-timed submissions, two families × two step counts (four
/// distinct `BatchKey`s in flight).
#[test]
fn prop_every_request_answered_exactly_once_any_worker_count() {
    let manifest = Manifest::builtin();
    forall(
        0xC0081,
        5,
        |r| {
            (
                gen::usize_in(r, 1, 4), // worker-pool size 1..=3
                gen::vec_of(r, 1, 9, |r| (r.below(2), r.below(2))),
            )
        },
        |case: &(usize, Vec<(usize, usize)>)| {
            let (workers, reqs) = case;
            let mut cfg =
                CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(*workers);
            cfg.max_wait = Duration::from_millis(5);
            let coord = Coordinator::start(cfg).map_err(|e| e.to_string())?;

            let trace =
                PoissonTrace::generate(300.0, reqs.len(), 10, 0, 0, 0xAC1D ^ *workers as u64);
            let t0 = Instant::now();
            let mut rxs = Vec::new();
            for (i, &(f, s)) in reqs.iter().enumerate() {
                let target = t0 + Duration::from_secs_f64(trace.items[i].arrival_s);
                if let Some(d) = target.checked_duration_since(Instant::now()) {
                    std::thread::sleep(d);
                }
                let family = ["image", "audio"][f];
                let req = Request {
                    id: 0,
                    family: family.into(),
                    cond: cond_for(family, i),
                    solver: SolverKind::Ddim,
                    steps: 2 + s,
                    cfg_scale: 1.0,
                    seed: i as u64,
                    policy: Policy::no_cache(),
                    compute: Default::default(),
                };
                rxs.push((family, coord.submit(req)));
            }

            for (family, rx) in &rxs {
                let resp = rx
                    .recv_timeout(Duration::from_secs(120))
                    .map_err(|_| "request never answered — deadline flush missing?".to_string())?
                    .map_err(|e| format!("request failed: {e}"))?;
                let fm = manifest.family(family).unwrap();
                let mut want = vec![1usize];
                want.extend(&fm.latent_shape);
                if resp.latent.shape != want {
                    return Err(format!(
                        "latent shape {:?} != {:?} for family {family} — batch mixed keys?",
                        resp.latent.shape, want
                    ));
                }
            }

            let m = coord.metrics();
            let n = reqs.len() as u64;
            if Metrics::get(&m.requests_submitted) != n {
                return Err(format!("submitted {} != {n}", Metrics::get(&m.requests_submitted)));
            }
            if Metrics::get(&m.requests_completed) != n {
                return Err(format!(
                    "completed {} != {n} (answered more or less than once)",
                    Metrics::get(&m.requests_completed)
                ));
            }
            if Metrics::get(&m.requests_failed) != 0 {
                return Err(format!("{} requests failed", Metrics::get(&m.requests_failed)));
            }
            coord.shutdown();
            // exactly once: the reply channels must now be disconnected
            // with no second message pending
            for (_, rx) in &rxs {
                match rx.try_recv() {
                    Err(std::sync::mpsc::TryRecvError::Disconnected) => {}
                    other => return Err(format!("reply channel not drained: {other:?}")),
                }
            }
            Ok(())
        },
    );
}

fn image_request(steps: usize, seed: u64, policy: Policy) -> Request {
    Request {
        id: 0,
        family: "image".into(),
        cond: Cond::Label(vec![(seed % 10) as i32]),
        solver: SolverKind::Ddim,
        steps,
        cfg_scale: 1.0,
        seed,
        policy,
        compute: Default::default(),
    }
}

/// ISSUE 3 tentpole contract: with one replica held inside a long
/// calibration, warm (priority-lane) batches must be served by the
/// idle sibling *while the calibration is still running*. Under the
/// old round-robin per-replica channels roughly half of these batches
/// queued behind the calibrating replica and completed only after it
/// finished — exactly the head-of-line failure the shared pull queue
/// removes.
#[test]
fn stuck_calibration_does_not_delay_warm_batches_on_siblings() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(2);
    cfg.max_wait = Duration::from_millis(5);
    cfg.calib_samples = 8; // deliberately long: 8 samples × 16 steps
    let coord = Coordinator::start(cfg).expect("coordinator");

    // cold smooth key → normal lane → one replica calibrates (generous
    // alpha: any populated error cell below it yields reuse, so skips
    // are guaranteed without pinning the untrained model's error scale)
    let cold_rx = coord.submit(image_request(16, 1, Policy::smooth(2.0)));

    // wait until a replica is demonstrably inside the calibration
    let t0 = Instant::now();
    while Metrics::get(&coord.metrics().calibrations) == 0 {
        assert!(
            t0.elapsed() < Duration::from_secs(120),
            "calibration never started"
        );
        std::thread::sleep(Duration::from_millis(2));
    }

    // warm traffic on the priority lane: both no-cache (no resolution at
    // all) AND fora:2 (a *resolving* calibration-free policy — it must
    // resolve without touching the store lock the calibration holds,
    // or the sibling would park on the mutex and the pool would be
    // head-of-line-blocked anyway)
    let warm_rxs: Vec<_> = (0..2)
        .map(|i| coord.submit(image_request(2, 10 + i, Policy::no_cache())))
        .chain((0..2).map(|i| coord.submit(image_request(2, 20 + i, Policy::fora(2)))))
        .collect();
    for rx in &warm_rxs {
        rx.recv_timeout(Duration::from_secs(120))
            .expect("warm request hung behind the calibrating replica")
            .expect("warm request failed");
    }
    // the sharp part: every warm response arrived while the cold
    // request was still in flight
    match cold_rx.try_recv() {
        Err(std::sync::mpsc::TryRecvError::Empty) => {}
        other => panic!(
            "cold request finished before the warm ones were all served: {other:?}"
        ),
    }
    let cold = cold_rx
        .recv_timeout(Duration::from_secs(300))
        .expect("cold request hung")
        .expect("cold request failed");
    assert!(cold.gen_stats.skip_fraction() > 0.0, "smooth α=2.0 should skip");

    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.calibrations), 1);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    assert_eq!(Metrics::get(&m.queue_rejections), 0);
    assert!(m.queue_wait.count() > 0, "executors must account queue wait");
    coord.shutdown();
}

/// ISSUE 3 admission-control contract: a burst far beyond
/// `--queue-depth` gets its overflow *rejected* with a well-formed
/// `overloaded:` error — rejected requests are answered immediately,
/// never hung, and the admitted ones still complete.
#[test]
fn queue_full_rejects_with_well_formed_overloaded_error() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir())
        .with_workers(1)
        .with_queue_depth(1);
    cfg.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(cfg).expect("coordinator");

    // 16 distinct step counts → 16 distinct BatchKeys → 16 batches
    // flushed nearly simultaneously into a depth-1 queue with a single
    // (busy) executor
    let rxs: Vec<_> = (0..16u64)
        .map(|i| coord.submit(image_request(2 + i as usize, i, Policy::no_cache())))
        .collect();

    let mut ok = 0u64;
    let mut rejected = 0u64;
    for rx in &rxs {
        match rx.recv_timeout(Duration::from_secs(120)) {
            Ok(Ok(resp)) => {
                assert_eq!(resp.latent.shape, vec![1, 16, 16, 4]);
                ok += 1;
            }
            Ok(Err(e)) => {
                let msg = format!("{e}");
                assert!(
                    msg.starts_with("overloaded:"),
                    "rejection must carry the overloaded error shape, got {msg:?}"
                );
                rejected += 1;
            }
            Err(_) => panic!("request neither served nor rejected (hang)"),
        }
    }
    assert_eq!(ok + rejected, 16);
    assert!(rejected >= 1, "a 16-batch burst into a depth-1 queue must reject");
    assert!(ok >= 1, "admission control must not reject everything");

    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.queue_rejections), rejected);
    assert_eq!(Metrics::get(&m.requests_completed), ok);
    assert_eq!(Metrics::get(&m.requests_submitted), 16);
    coord.shutdown();
}

/// ISSUE 5 (f): a request cancelled while *queued* is answered with a
/// `cancelled:` error immediately, frees its admission slot (a request
/// the full queue just rejected is admitted right after), and never
/// reaches a replica.
#[test]
fn cancelling_a_queued_request_frees_its_admission_slot() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir())
        .with_workers(1)
        .with_queue_depth(1);
    cfg.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(cfg).expect("coordinator");

    // occupy the single executor with a long generation (distinct step
    // counts keep every request in its own batch)
    let (ptx, prx) = std::sync::mpsc::channel();
    let a = coord.submit_opts(
        image_request(800, 1, Policy::no_cache()),
        SubmitOpts { progress: Some(ptx), deadline: None },
    );
    prx.recv_timeout(Duration::from_secs(120)).expect("executor never started A");

    // B fills the depth-1 queue…
    let b = coord.submit_opts(image_request(4, 2, Policy::no_cache()), SubmitOpts::default());
    let t0 = Instant::now();
    while coord.queue_len() == 0 {
        assert!(t0.elapsed() < Duration::from_secs(60), "B never queued");
        std::thread::sleep(Duration::from_millis(2));
    }
    // …so C is rejected at admission
    let c = coord.submit_opts(image_request(5, 3, Policy::no_cache()), SubmitOpts::default());
    let c_err = c
        .reply
        .recv_timeout(Duration::from_secs(60))
        .expect("C must be answered")
        .expect_err("C must be rejected");
    assert!(format!("{c_err}").starts_with("overloaded:"), "{c_err}");

    // cancelling B answers it promptly and frees the slot *now* — no
    // waiting for the long batch A to finish
    assert!(coord.cancel(b.id), "B must be known while queued");
    let b_err = b
        .reply
        .recv_timeout(Duration::from_secs(5))
        .expect("cancelled queued request must be answered immediately")
        .expect_err("B must not have executed");
    assert!(format!("{b_err}").starts_with("cancelled:"), "{b_err}");
    assert_eq!(coord.queue_len(), 0, "cancelled request must free its slot");

    // the freed slot admits new work, which completes once A is gone
    let d = coord.submit_opts(image_request(6, 4, Policy::no_cache()), SubmitOpts::default());
    assert!(coord.cancel(a.id), "A must be known while executing");
    let a_err = a
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("cancelled in-flight request must be answered")
        .expect_err("A must have been aborted");
    assert!(format!("{a_err}").starts_with("cancelled:"), "{a_err}");
    let d_resp = d
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("D must be answered")
        .expect("D must complete");
    assert_eq!(d_resp.latent.shape, vec![1, 16, 16, 4]);

    // (h) counters reconcile: 4 submitted = 1 completed + 2 cancelled +
    // 1 rejected, nothing failed, nothing lost or double-answered
    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.requests_submitted), 4);
    assert_eq!(Metrics::get(&m.requests_completed), 1);
    assert_eq!(Metrics::get(&m.requests_cancelled), 2);
    assert_eq!(Metrics::get(&m.queue_rejections), 1);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    coord.shutdown();
    for rx in [&a.reply, &b.reply, &c.reply, &d.reply] {
        match rx.try_recv() {
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {}
            other => panic!("reply channel not drained exactly once: {other:?}"),
        }
    }
}

/// ISSUE 5 (g): cancelling an in-flight generation stops executor work
/// at the next solver-step boundary — pinned by watching per-step
/// progress events: after the cancel, only a bounded number of further
/// steps may execute (scheduling slack), not the remaining trajectory.
#[test]
fn cancelling_inflight_generation_stops_within_a_step() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(1);
    cfg.max_wait = Duration::from_millis(1);
    let coord = Coordinator::start(cfg).expect("coordinator");

    let steps = 600usize;
    let (ptx, prx) = std::sync::mpsc::channel();
    let ticket = coord.submit_opts(
        image_request(steps, 1, Policy::no_cache()),
        SubmitOpts { progress: Some(ptx), deadline: None },
    );
    // first progress event ⇒ the generation is demonstrably in flight
    let first = prx.recv_timeout(Duration::from_secs(120)).expect("no progress event");
    assert_eq!(first.id, ticket.id);
    assert_eq!(first.steps, steps);
    assert!(coord.cancel(ticket.id));

    let err = ticket
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("cancelled request must be answered")
        .expect_err("cancelled request must not complete");
    assert!(format!("{err}").starts_with("cancelled:"), "{err}");

    // the executor checked between steps: the trajectory was abandoned
    // long before its 600 steps (progress events stop almost at once)
    let mut last_step = first.step;
    while let Ok(p) = prx.try_recv() {
        last_step = p.step;
    }
    assert!(
        last_step + 1 < steps / 2,
        "cancel was not prompt: reached step {last_step} of {steps}"
    );
    let m = coord.metrics();
    assert!(Metrics::get(&m.steps_executed) < (steps / 2) as u64);
    assert_eq!(Metrics::get(&m.requests_cancelled), 1);
    assert_eq!(Metrics::get(&m.requests_completed), 0);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    coord.shutdown();
}

/// ISSUE 5 (g), the sharp half: cancellation stays prompt and safe
/// while a *sibling replica* holds the `smooth:*` calibration lock —
/// the cancelled no-cache batch never touches the plan store, so the
/// in-flight calibration cannot delay the abort, and both requests'
/// counters reconcile afterwards.
#[test]
fn cancel_is_prompt_while_sibling_holds_calibration_lock() {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(2);
    cfg.max_wait = Duration::from_millis(5);
    cfg.calib_samples = 8; // deliberately long calibration
    let coord = Coordinator::start(cfg).expect("coordinator");

    // cold smooth key → replica 1 enters calibration (and holds the
    // shared plan-store lock)
    let cold_rx = coord.submit(image_request(16, 1, Policy::smooth(2.0)));
    let t0 = Instant::now();
    while Metrics::get(&coord.metrics().calibrations) == 0 {
        assert!(t0.elapsed() < Duration::from_secs(120), "calibration never started");
        std::thread::sleep(Duration::from_millis(2));
    }

    // long no-cache request lands on the idle sibling…
    let (ptx, prx) = std::sync::mpsc::channel();
    let ticket = coord.submit_opts(
        image_request(600, 2, Policy::no_cache()),
        SubmitOpts { progress: Some(ptx), deadline: None },
    );
    prx.recv_timeout(Duration::from_secs(120)).expect("sibling never started the long batch");
    // …and is cancelled mid-flight while the calibration still runs
    assert!(coord.cancel(ticket.id));
    let cancel_sent = Instant::now();
    let err = ticket
        .reply
        .recv_timeout(Duration::from_secs(120))
        .expect("cancelled request must be answered despite the held calibration lock")
        .expect_err("cancelled request must not complete");
    assert!(format!("{err}").starts_with("cancelled:"), "{err}");
    let abort_latency = cancel_sent.elapsed();

    // the calibrating request is untouched: it completes and skips
    let cold = cold_rx
        .recv_timeout(Duration::from_secs(300))
        .expect("cold request hung")
        .expect("cold request failed");
    assert!(cold.gen_stats.skip_fraction() > 0.0);

    let m = coord.metrics();
    assert_eq!(Metrics::get(&m.calibrations), 1);
    assert_eq!(Metrics::get(&m.requests_cancelled), 1);
    assert_eq!(Metrics::get(&m.requests_completed), 1);
    assert_eq!(Metrics::get(&m.requests_failed), 0);
    // promptness: far faster than the 600-step trajectory (whose steps
    // kept pace with the 16-step calibration batches on the sibling)
    assert!(
        abort_latency < Duration::from_secs(60),
        "abort took {abort_latency:?} — cancellation blocked behind the calibration?"
    );
    coord.shutdown();
}

/// Batcher-layer property with synthetic clocks (no sleeping): under
/// Poisson inter-arrival offsets, every request flushes by
/// `last_arrival + max_wait`, every flushed batch is homogeneous in
/// `BatchKey`, and no batch exceeds the effective max size.
#[test]
fn prop_deadline_flushes_fire_under_poisson_arrivals() {
    forall(
        0xF1054,
        40,
        |r| gen::vec_of(r, 1, 30, |r| (r.below(3), r.below(2))),
        |seq: &Vec<(usize, usize)>| {
            let max_wait = Duration::from_millis(50);
            let config = BatcherConfig {
                supported_batches: vec![1, 2, 4, 8],
                max_wait,
            };
            let mut batcher = Batcher::new(config);
            let trace = PoissonTrace::generate(100.0, seq.len(), 10, 0, 0, seq.len() as u64);
            let t0 = Instant::now();
            let families = ["image", "audio", "video"];
            let mut keep_rx = Vec::new(); // keep reply receivers alive
            let mut flushed = 0usize;
            let check_batches = |batches: Vec<Vec<InFlight>>| -> Result<usize, String> {
                let mut count = 0;
                for batch in batches {
                    let key = batch[0].request.batch_key();
                    if batch.len() > 8 {
                        return Err(format!("batch of {} exceeds max", batch.len()));
                    }
                    for it in &batch {
                        if it.request.batch_key() != key {
                            return Err("batch mixes BatchKeys".into());
                        }
                    }
                    count += batch.len();
                }
                Ok(count)
            };
            let mut last = t0;
            for (i, &(f, s)) in seq.iter().enumerate() {
                let now = t0 + Duration::from_secs_f64(trace.items[i].arrival_s);
                last = now;
                let (tx, rx) = std::sync::mpsc::channel();
                keep_rx.push(rx);
                let item = InFlight::new(
                    Request {
                        id: i as u64,
                        family: families[f].into(),
                        cond: cond_for(families[f], i),
                        solver: SolverKind::Ddim,
                        steps: 10 + s,
                        cfg_scale: 1.0,
                        seed: i as u64,
                        policy: Policy::no_cache(),
                        compute: Default::default(),
                    },
                    tx,
                );
                if let Some(batch) = batcher.push(item, now) {
                    flushed += check_batches(vec![batch])?;
                }
                flushed += check_batches(batcher.poll(now))?;
            }
            // one deadline sweep after the last arrival must drain all
            flushed += check_batches(batcher.poll(last + max_wait))?;
            if batcher.pending() != 0 {
                return Err(format!(
                    "{} requests stranded past the flush deadline",
                    batcher.pending()
                ));
            }
            if flushed != seq.len() {
                return Err(format!("flushed {flushed} != submitted {}", seq.len()));
            }
            Ok(())
        },
    );
}
