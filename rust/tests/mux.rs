//! Protocol v2 integration (ISSUE 9): one framed connection carrying
//! many concurrent generations with interleaved step streams, bitwise
//! parity with v1, exactly-once responses, credit-window flow control,
//! v1/v2 coexistence on one listener, malformed-frame recovery, typed
//! client timeouts against a dead server, and `Client2` reconnects.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

use smoothcache::coordinator::{Coordinator, CoordinatorConfig};
use smoothcache::server::frame::{Decoded, Frame, FrameReader, FrameType, MAGIC, MAX_FRAME_LEN};
use smoothcache::server::{Client, Client2, Client2Config, Server, ServerOpts};
use smoothcache::util::json::Json;

fn coord() -> Coordinator {
    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(10);
    cfg.calib_samples = 2;
    Coordinator::start(cfg).expect("coordinator")
}

/// A generation request envelope keyed only by `seed`, so v1 and v2
/// paths can be compared bitwise.
fn gen_req(seed: u64) -> Json {
    Json::obj()
        .set("family", "image")
        .set("label", (seed % 10) as f64)
        .set("steps", 6usize)
        .set("solver", "ddim")
        .set("policy", "fora:2")
        .set("seed", seed)
        .set("return_latent", true)
}

/// Minimal frame-level v2 client for protocol tests: performs the
/// `SMC2` + hello handshake and exchanges raw frames.
struct RawV2 {
    stream: TcpStream,
    reader: FrameReader,
}

impl RawV2 {
    fn handshake(addr: &SocketAddr) -> RawV2 {
        RawV2::handshake_with_credits(addr).0
    }

    /// Handshake, also returning the server-announced credit window.
    fn handshake_with_credits(addr: &SocketAddr) -> (RawV2, u64) {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream.set_read_timeout(Some(Duration::from_millis(20))).unwrap();
        stream.write_all(&MAGIC).unwrap();
        Frame::json(FrameType::Hello, 0, &Json::obj().set("version", 2usize))
            .write_to(&mut stream)
            .unwrap();
        stream.flush().unwrap();
        let mut raw = RawV2 { stream, reader: FrameReader::new(MAX_FRAME_LEN) };
        let hello = raw.read_frame(Duration::from_secs(120));
        assert_eq!(hello.frame_type, FrameType::Hello, "{hello:?}");
        let body = hello.payload_json().expect("hello payload");
        let credits = body.get("credits").and_then(|v| v.as_u64()).expect("credits");
        assert_eq!(body.get("version").and_then(|v| v.as_u64()), Some(2));
        (raw, credits)
    }

    fn send(&mut self, f: &Frame) {
        f.write_to(&mut self.stream).unwrap();
        self.stream.flush().unwrap();
    }

    fn read_frame(&mut self, timeout: Duration) -> Frame {
        let t0 = Instant::now();
        loop {
            match self.reader.decode() {
                Decoded::Frame(f) => return f,
                Decoded::Malformed(e) => panic!("malformed frame from server: {e}"),
                Decoded::Incomplete => {}
            }
            assert!(t0.elapsed() < timeout, "no frame within {timeout:?}");
            let mut buf = [0u8; 8192];
            match self.stream.read(&mut buf) {
                Ok(0) => panic!("connection closed while waiting for a frame"),
                Ok(n) => self.reader.extend(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
                Err(e) => panic!("read: {e}"),
            }
        }
    }
}

#[test]
fn one_v2_connection_multiplexes_streams_with_v1_parity_and_exactly_once() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    const STREAMS: u64 = 8;

    // v1 reference latents, serially, one seed per stream
    let mut references = Vec::new();
    {
        let mut v1 = Client::connect(&server.addr).expect("v1 client");
        for seed in 0..STREAMS {
            let resp = v1.call(&gen_req(seed)).expect("v1 call");
            assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
            references.push(resp.get("latent").unwrap().as_f32_vec().unwrap());
        }
    } // drop v1: frees its connection-handler slot

    // the same 8 generations concurrently over ONE v2 connection
    let (mut v2, credits) = RawV2::handshake_with_credits(&server.addr);
    assert_eq!(credits, 32, "default --conn-inflight window");
    for seed in 0..STREAMS {
        let req = gen_req(seed).set("stream", true);
        v2.send(&Frame::json(FrameType::Request, seed + 1, &req));
    }

    let mut responses: std::collections::HashMap<u64, Json> = Default::default();
    let mut steps_per_id: std::collections::HashMap<u64, u64> = Default::default();
    let mut ids_stepped_before_first_response = std::collections::HashSet::new();
    let mut credits_returned = 0u64;
    while responses.len() < STREAMS as usize {
        let f = v2.read_frame(Duration::from_secs(120));
        match f.frame_type {
            FrameType::Step => {
                let ev = f.payload_json().expect("step payload");
                if ev.get("event").and_then(|v| v.as_str()) == Some("step") {
                    *steps_per_id.entry(f.id).or_insert(0) += 1;
                    if responses.is_empty() {
                        ids_stepped_before_first_response.insert(f.id);
                    }
                }
            }
            FrameType::Response => {
                let body = f.payload_json().expect("response payload");
                assert_eq!(body.get("ok").unwrap().as_bool(), Some(true), "{body:?}");
                let prev = responses.insert(f.id, body);
                assert!(prev.is_none(), "duplicate response for id {}", f.id);
            }
            FrameType::Credit => credits_returned += 1,
            other => panic!("unexpected {other:?} frame: {f:?}"),
        }
    }
    // drain trailing credit frames (the terminal response for the last
    // stream can arrive just before its credit)
    while credits_returned < STREAMS {
        let f = v2.read_frame(Duration::from_secs(120));
        assert_eq!(f.frame_type, FrameType::Credit, "{f:?}");
        credits_returned += 1;
    }

    // exactly-once terminal responses, ≥1 step event per stream, and
    // demonstrably interleaved streams on the shared connection
    assert_eq!(responses.len() as u64, STREAMS);
    assert_eq!(credits_returned, STREAMS, "one credit per answered request");
    for id in 1..=STREAMS {
        assert!(steps_per_id.get(&id).copied().unwrap_or(0) >= 1, "no steps for id {id}");
    }
    assert!(
        ids_stepped_before_first_response.len() >= 2,
        "step streams never interleaved: {ids_stepped_before_first_response:?}"
    );

    // bitwise parity with the v1 serial references
    for id in 1..=STREAMS {
        let body = &responses[&id];
        let latent = body.get("latent").unwrap().as_f32_vec().unwrap();
        assert_eq!(
            latent,
            references[(id - 1) as usize],
            "v2 stream {id} diverged from its v1 reference"
        );
    }
    server.stop();
}

#[test]
fn credit_window_rejects_excess_requests_and_replenishes() {
    let c = Arc::new(coord());
    let opts = ServerOpts { conn_threads: 2, conn_inflight: 2, ..ServerOpts::default() };
    let server = Server::start_with("127.0.0.1:0", Arc::clone(&c), opts).expect("server");
    let (mut v2, credits) = RawV2::handshake_with_credits(&server.addr);
    assert_eq!(credits, 2, "hello must announce the configured window");

    // two slow generations fill the window; frames are dispatched in
    // order, so the third request deterministically sees it full
    let slow = Json::obj()
        .set("family", "image")
        .set("label", 1.0)
        .set("steps", 200usize)
        .set("policy", "no-cache")
        .set("seed", 3u64);
    v2.send(&Frame::json(FrameType::Request, 1, &slow));
    v2.send(&Frame::json(FrameType::Request, 2, &slow.clone().set("seed", 4u64)));
    v2.send(&Frame::json(FrameType::Request, 3, &gen_req(5)));

    let mut rejected: Option<Json> = None;
    let mut completed = std::collections::HashSet::new();
    let mut credits_returned = 0u64;
    while credits_returned < 3 {
        let f = v2.read_frame(Duration::from_secs(120));
        match f.frame_type {
            FrameType::Response => {
                let body = f.payload_json().expect("response payload");
                if f.id == 3 {
                    rejected = Some(body);
                } else {
                    assert_eq!(body.get("ok").unwrap().as_bool(), Some(true), "{body:?}");
                    completed.insert(f.id);
                }
            }
            FrameType::Credit => credits_returned += 1,
            FrameType::Step => {}
            other => panic!("unexpected {other:?} frame: {f:?}"),
        }
    }
    let rejected = rejected.expect("request 3 never answered");
    assert_eq!(rejected.get("ok").unwrap().as_bool(), Some(false), "{rejected:?}");
    assert_eq!(rejected.get("overloaded").and_then(|v| v.as_bool()), Some(true), "{rejected:?}");
    let msg = rejected.get("error").and_then(|v| v.as_str()).unwrap_or("");
    assert!(msg.starts_with("overloaded:"), "typed overload error, got {msg:?}");
    let expect: std::collections::HashSet<u64> = [1, 2].into_iter().collect();
    assert_eq!(completed, expect, "window occupants must finish");

    // every credit came back, so the window accepts new work again
    v2.send(&Frame::json(FrameType::Request, 4, &gen_req(6)));
    loop {
        let f = v2.read_frame(Duration::from_secs(120));
        if f.frame_type == FrameType::Response {
            assert_eq!(f.id, 4);
            let body = f.payload_json().expect("response payload");
            assert_eq!(body.get("ok").unwrap().as_bool(), Some(true), "{body:?}");
            break;
        }
    }
    server.stop();
}

#[test]
fn client2_enforces_its_credit_window_with_typed_errors() {
    let c = Arc::new(coord());
    let opts = ServerOpts { conn_threads: 2, conn_inflight: 1, ..ServerOpts::default() };
    let server = Server::start_with("127.0.0.1:0", Arc::clone(&c), opts).expect("server");
    let v2 = Client2::connect(&server.addr).expect("client2");

    // occupy the single-slot window with a long generation...
    let long = Json::obj()
        .set("family", "image")
        .set("label", 1.0)
        .set("steps", 2000usize)
        .set("policy", "no-cache")
        .set("seed", 3u64);
    let handle = v2.submit(&long).expect("submit");
    // ...so the next submit is refused client-side, before any bytes
    // hit the wire
    let err = v2.submit(&gen_req(1)).expect_err("window is full");
    assert!(err.to_string().starts_with("overloaded:"), "{err}");

    // cancelling the occupant returns the credit and unblocks the window
    handle.cancel();
    let outcome = handle.wait().expect("terminal response");
    assert_eq!(outcome.get("ok").unwrap().as_bool(), Some(false), "{outcome:?}");
    assert_eq!(outcome.get("cancelled").and_then(|v| v.as_bool()), Some(true), "{outcome:?}");
    let resp = v2.call(&gen_req(2)).expect("post-cancel call");
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    server.stop();
}

#[test]
fn listener_serves_v1_and_v2_concurrently_with_identical_results() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 3).expect("server");

    // both protocols live on the same port at the same time
    let mut v1 = Client::connect(&server.addr).expect("v1 client");
    let v2 = Client2::connect(&server.addr).expect("v2 client");
    assert!(v1.ping().unwrap());
    assert!(v2.ping().unwrap());

    let from_v1 = v1.call(&gen_req(11)).expect("v1 call");
    let from_v2 = v2.call(&gen_req(11)).expect("v2 call");
    assert_eq!(from_v1.get("ok").unwrap().as_bool(), Some(true), "{from_v1:?}");
    assert_eq!(from_v2.get("ok").unwrap().as_bool(), Some(true), "{from_v2:?}");
    assert_eq!(
        from_v1.get("latent").unwrap().as_f32_vec().unwrap(),
        from_v2.get("latent").unwrap().as_f32_vec().unwrap(),
        "the framed protocol must not change the generated latent"
    );

    // v2 streaming delivers the same ordered per-step events as v1
    let mut events = Vec::new();
    let done = v2
        .call_streaming(&gen_req(12), |ev| {
            if ev.get("event").and_then(|v| v.as_str()) == Some("step") {
                events.push(ev.get("step").and_then(|v| v.as_u64()).unwrap());
            }
        })
        .expect("v2 streaming");
    assert_eq!(done.get("ok").unwrap().as_bool(), Some(true), "{done:?}");
    assert_eq!(events, vec![0, 1, 2, 3, 4, 5], "one ordered event per step");

    // v1 still works after v2 traffic; metrics served over both
    assert!(v1.metrics_summary().unwrap().contains("v2_conns="));
    assert!(v2.metrics_summary().unwrap().contains("completed="));
    server.stop();
}

#[test]
fn malformed_frames_get_typed_errors_and_never_kill_other_streams() {
    let c = Arc::new(coord());
    let opts = ServerOpts { conn_threads: 2, max_frame: 4096, ..ServerOpts::default() };
    let server = Server::start_with("127.0.0.1:0", Arc::clone(&c), opts).expect("server");
    let mut v2 = RawV2::handshake(&server.addr);

    // unknown frame type → typed error, connection survives
    let mut junk = Vec::new();
    junk.extend_from_slice(&0u32.to_le_bytes());
    junk.push(99u8);
    junk.extend_from_slice(&5u64.to_le_bytes());
    v2.stream.write_all(&junk).unwrap();
    v2.stream.flush().unwrap();
    let err = v2.read_frame(Duration::from_secs(120));
    assert_eq!(err.frame_type, FrameType::Error, "{err:?}");
    assert!(err.payload_str().contains("unknown frame type 99"), "{err:?}");

    // oversized declared length → typed error on sight of the header;
    // the decoder then skips the declared extent, so sending the whole
    // bloated frame leaves the stream aligned for what follows
    let mut huge = Vec::new();
    huge.extend_from_slice(&8192u32.to_le_bytes());
    huge.push(FrameType::Ping.byte());
    huge.extend_from_slice(&6u64.to_le_bytes());
    huge.extend_from_slice(&vec![0x20u8; 8192]);
    v2.stream.write_all(&huge).unwrap();
    v2.stream.flush().unwrap();
    let err = v2.read_frame(Duration::from_secs(120));
    assert_eq!(err.frame_type, FrameType::Error, "{err:?}");
    assert!(err.payload_str().contains("exceeds max"), "{err:?}");

    // a duplicate in-flight id is refused without touching the original
    // stream: start a long generation, duplicate its id, then cancel —
    // the original still gets its own (cancelled) terminal response
    let long = Json::obj()
        .set("family", "image")
        .set("label", 1.0)
        .set("steps", 2000usize)
        .set("policy", "no-cache")
        .set("stream", true)
        .set("seed", 3u64);
    v2.send(&Frame::json(FrameType::Request, 7, &long));
    // wait for the accepted event so id 7 is in flight
    loop {
        let f = v2.read_frame(Duration::from_secs(120));
        if f.frame_type == FrameType::Step
            && f.payload_json()
                .and_then(|ev| ev.get("event").and_then(|v| v.as_str().map(String::from)))
                .as_deref()
                == Some("accepted")
        {
            break;
        }
    }
    v2.send(&Frame::json(FrameType::Request, 7, &gen_req(1)));
    let mut saw_duplicate_error = false;
    let outcome = loop {
        let f = v2.read_frame(Duration::from_secs(120));
        match f.frame_type {
            FrameType::Error => {
                assert!(
                    f.payload_str().contains("duplicate in-flight request id 7"),
                    "{f:?}"
                );
                saw_duplicate_error = true;
                // now tear down the long generation
                v2.send(&Frame::empty(FrameType::Cancel, 7));
            }
            FrameType::Response => break f.payload_json().expect("response payload"),
            FrameType::Step | FrameType::Credit => {}
            other => panic!("unexpected {other:?} frame: {f:?}"),
        }
    };
    assert!(saw_duplicate_error, "duplicate id was never reported");
    assert_eq!(outcome.get("ok").unwrap().as_bool(), Some(false), "{outcome:?}");
    assert_eq!(outcome.get("cancelled").and_then(|v| v.as_bool()), Some(true), "{outcome:?}");

    // the connection still serves after all three violations
    v2.send(&Frame::json(FrameType::Request, 8, &gen_req(2)));
    loop {
        let f = v2.read_frame(Duration::from_secs(120));
        if f.frame_type == FrameType::Response {
            assert_eq!(f.id, 8);
            let body = f.payload_json().expect("response payload");
            assert_eq!(body.get("ok").unwrap().as_bool(), Some(true), "{body:?}");
            break;
        }
    }

    // a truncated frame (header cut short, then EOF) is answered with a
    // best-effort typed error before the server closes the connection
    let mut cut = RawV2::handshake(&server.addr);
    cut.stream.write_all(&[0x20, 0x00]).unwrap(); // 2 of 13 header bytes
    cut.stream.flush().unwrap();
    cut.stream.shutdown(std::net::Shutdown::Write).unwrap();
    let t0 = Instant::now();
    let mut saw_truncated = false;
    let mut buf = [0u8; 4096];
    'read: loop {
        assert!(t0.elapsed() < Duration::from_secs(120), "no truncation error before close");
        match cut.stream.read(&mut buf) {
            Ok(0) => break 'read, // server closed after (maybe) reporting
            Ok(n) => {
                cut.reader.extend(&buf[..n]);
                while let Decoded::Frame(f) = cut.reader.decode() {
                    if f.frame_type == FrameType::Error && f.payload_str().contains("truncated") {
                        saw_truncated = true;
                        break 'read;
                    }
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {}
            Err(_) => break 'read,
        }
    }
    assert!(saw_truncated, "truncated frame was never reported");
    server.stop();
}

#[test]
fn clients_report_typed_timeouts_against_an_unresponsive_server() {
    // a bound listener that never accepts: connects succeed (backlog),
    // but no byte ever comes back
    let sink = TcpListener::bind("127.0.0.1:0").expect("bind");
    let addr = sink.local_addr().unwrap();

    // v1: the call times out with a typed error instead of hanging
    let mut v1 = Client::connect_with(&addr, Duration::from_millis(200)).expect("tcp connect");
    let err = v1.call(&gen_req(1)).expect_err("no server behind the socket");
    assert!(err.to_string().contains("timeout"), "typed timeout, got: {err}");

    // v2: the eager hello handshake times out with a typed error
    let cfg = Client2Config {
        pool: 1,
        connect_timeout: Duration::from_millis(300),
        io_timeout: Duration::from_millis(300),
    };
    let err = Client2::with_config(&addr, cfg).expect_err("no hello ever arrives");
    assert!(err.to_string().contains("timeout"), "typed timeout, got: {err}");
    drop(sink);
}

#[test]
fn client2_reconnects_after_its_connections_break() {
    let c = Arc::new(coord());
    let server = Server::start("127.0.0.1:0", Arc::clone(&c), 2).expect("server");
    let v2 = Client2::connect(&server.addr).expect("client2");
    let first = v2.call(&gen_req(21)).expect("first call");
    assert_eq!(first.get("ok").unwrap().as_bool(), Some(true), "{first:?}");

    // sever every pooled socket in place (broken pipe on next write),
    // then call again: submit must transparently reconnect and succeed
    v2.reset();
    let second = v2.call(&gen_req(22)).expect("call after reset");
    assert_eq!(second.get("ok").unwrap().as_bool(), Some(true), "{second:?}");
    server.stop();
}
