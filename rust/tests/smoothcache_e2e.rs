//! End-to-end SmoothCache integration: calibrate → generate schedule →
//! run cached generation → verify the paper's core behaviours (real
//! skips, bounded quality drift, monotonicity in alpha, determinism).
//! Runs against whatever backend the engine selects — the pure-Rust
//! reference backend offline, PJRT artifacts when built and present.

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef, Schedule};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, GenConfig};
use smoothcache::quality::psnr;
use smoothcache::solvers::SolverKind;

fn engine_with(family: &str) -> Engine {
    let mut e = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    e.load_family(family).expect("load");
    e
}

#[test]
fn calibrate_then_cache_image_family() {
    let engine = engine_with("image");
    let cc = CalibrationConfig {
        steps: 12,
        num_samples: 2,
        k_max: 3,
        ..CalibrationConfig::new(SolverKind::Ddim, 12)
    };
    let curves = calibrate(&engine, "image", &cc).expect("calibrate");
    assert_eq!(curves.num_samples, 2);

    // every (step >= 1, k=1) cell observed for both branch types
    for bt in ["attn", "ffn"] {
        for s in 1..12 {
            let m = curves.mean(bt, s, 1).expect("cell populated");
            assert!(m.is_finite() && m >= 0.0);
        }
    }

    let fm = engine.family_manifest("image").unwrap().clone();
    let bts = fm.branch_types.clone();
    let sites = fm.branch_sites();
    let cond = Cond::Label(vec![3]);
    let base_cfg = GenConfig::new("image", SolverKind::Ddim, 12).with_seed(42);

    // no-cache reference
    let no_cache = CachePlan::no_cache(12, &sites);
    let reference =
        generate(&engine, &base_cfg, &cond, PlanRef::Plan(&no_cache), None).expect("gen");
    assert_eq!(reference.stats.branch_computes, 12 * 12); // 6 blocks × 2 types × 12 steps
    assert_eq!(reference.stats.branch_reuses, 0);

    // schedules at increasing alpha: more skips, bounded quality drift
    let mut prev_skip = -1.0;
    for alpha in [0.05, 0.15, 0.4] {
        let schedule = curves.smoothcache_schedule(alpha, &bts);
        schedule.validate().unwrap();
        let skip = schedule.skip_fraction();
        assert!(skip >= prev_skip, "alpha={alpha}");
        prev_skip = skip;

        let plan = CachePlan::from_grouped(&schedule, &sites).expect("plan");
        let out = generate(&engine, &base_cfg, &cond, PlanRef::Plan(&plan), None)
            .expect("cached gen");
        let expected_computes: usize =
            schedule.computes_per_type().iter().sum::<usize>() * 6; // × depth
        assert_eq!(out.stats.branch_computes, expected_computes);
        assert_eq!(
            out.stats.branch_computes + out.stats.branch_reuses,
            12 * 12
        );
        // same-seed trajectories stay comparable (finite PSNR, same shape)
        assert_eq!(out.latent.shape, reference.latent.shape);
        // PSNR vs the no-cache run: +inf when the schedule skips nothing
        // (identical trajectories), otherwise finite but reasonable.
        let p = psnr(&reference.latent, &out.latent);
        assert!(p > 3.0, "alpha={alpha} psnr={p}");
    }
}

#[test]
fn cached_generation_is_deterministic() {
    let engine = engine_with("image");
    let fm = engine.family_manifest("image").unwrap().clone();
    let schedule = Schedule::fora(8, &fm.branch_types, 2);
    let plan = CachePlan::from_grouped(&schedule, &fm.branch_sites()).unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, 8).with_seed(7);
    let cond = Cond::Label(vec![1]);
    let a = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
    let b = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
    assert_eq!(a.latent.data, b.latent.data);
    // different seed diverges
    let c = generate(
        &engine,
        &GenConfig::new("image", SolverKind::Ddim, 8).with_seed(8),
        &cond,
        PlanRef::Plan(&plan),
        None,
    )
    .unwrap();
    assert_ne!(a.latent.data, c.latent.data);
}

#[test]
fn cfg_generation_and_fora_on_audio() {
    let engine = engine_with("audio");
    let fm = engine.family_manifest("audio").unwrap().clone();
    let schedule = Schedule::fora(6, &fm.branch_types, 2);
    let plan = CachePlan::from_grouped(&schedule, &fm.branch_sites()).unwrap();
    let cfg = GenConfig::new("audio", SolverKind::DpmPP3M { sde: true }, 6)
        .with_cfg(7.0)
        .with_seed(5);
    let cond = Cond::Prompt((1..=fm.cond_len as i32).collect());
    let out = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
    assert_eq!(out.latent.shape, vec![1, 64, 8]);
    assert!(out.latent.data.iter().all(|v| v.is_finite()));
    assert!(out.stats.branch_reuses > 0);
}

#[test]
fn video_family_generates_with_rf() {
    let engine = engine_with("video");
    let fm = engine.family_manifest("video").unwrap().clone();
    let cfg = GenConfig::new("video", SolverKind::RectifiedFlow, 4).with_seed(3);
    let cond = Cond::Prompt(vec![9; fm.cond_len]);
    let no_cache = CachePlan::no_cache(4, &fm.branch_sites());
    let out = generate(&engine, &cfg, &cond, PlanRef::Plan(&no_cache), None).unwrap();
    assert_eq!(out.latent.shape, vec![1, 4, 8, 8, 4]);
    assert_eq!(out.stats.branch_computes, 4 * fm.depth * fm.branch_types.len());
}

#[test]
fn per_site_plan_matches_grouped_when_uniform() {
    let engine = engine_with("image");
    let fm = engine.family_manifest("image").unwrap().clone();
    let sites = fm.branch_sites();
    let schedule = Schedule::fora(6, &fm.branch_types, 2);
    // expand the grouped schedule into an identical per-site map
    let mut map = std::collections::BTreeMap::new();
    for b in 0..fm.depth {
        for bt in &fm.branch_types {
            let ds: Vec<_> = (0..6).map(|s| schedule.decision(s, bt)).collect();
            map.insert(format!("{b}.{bt}"), ds);
        }
    }
    let grouped = CachePlan::from_grouped(&schedule, &sites).unwrap();
    let per_site = CachePlan::from_site_map("uniform", 6, &sites, &map).unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, 6).with_seed(11);
    let cond = Cond::Label(vec![2]);
    let a = generate(&engine, &cfg, &cond, PlanRef::Plan(&grouped), None).unwrap();
    let b = generate(&engine, &cfg, &cond, PlanRef::Plan(&per_site), None).unwrap();
    assert_eq!(a.latent.data, b.latent.data);
}

#[test]
fn mismatched_plans_are_rejected_loudly() {
    let engine = engine_with("image");
    let fm = engine.family_manifest("image").unwrap().clone();
    let cond = Cond::Label(vec![1]);
    // wrong step count
    let plan = CachePlan::no_cache(5, &fm.branch_sites());
    let cfg = GenConfig::new("image", SolverKind::Ddim, 6).with_seed(1);
    assert!(generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).is_err());
    // plan built for another family's site set (audio) must not be
    // silently accepted with unmatched sites defaulting to Compute
    let mut audio_engine = smoothcache::model::Engine::open(smoothcache::artifacts_dir())
        .expect("engine");
    audio_engine.load_family("audio").expect("audio");
    let afm = audio_engine.family_manifest("audio").unwrap().clone();
    let audio_plan = CachePlan::no_cache(6, &afm.branch_sites());
    let err = generate(&engine, &cfg, &cond, PlanRef::Plan(&audio_plan), None)
        .expect_err("family mismatch must fail");
    assert!(format!("{err}").contains("sites"), "{err}");
}
