//! GenSession ↔ driver parity (ISSUE 5 acceptance): `generate` /
//! `generate_from` are thin drivers over the step-driven
//! [`GenSession`], and manual stepping must produce **bitwise**
//! identical latents and identical decision counters for every policy
//! in the registry, across two families × {ddim, rf} — plus the
//! session-only surfaces: per-step events that reconcile with the
//! final stats, interim latent access, and early exit.

use smoothcache::cache::plan::{parse_policy, registry, PlanRef};
use smoothcache::coordinator::{PlanStore, Policy};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, generate_from, GenConfig, GenSession};
use smoothcache::solvers::{SolverKind, SolverRun};
use smoothcache::util::rng::Rng;

/// One wire spelling per registry row (generous parameters so smooth /
/// drift actually skip on the untrained model). The length assertion
/// forces this list to grow with the registry.
fn registry_wires() -> Vec<&'static str> {
    let wires = vec![
        "no-cache",
        "fora:2",
        "alternate",
        "smooth:2.0",
        "smooth-persite:2.0",
        "delta-dit:2",
        "drift:1e9",
    ];
    assert_eq!(
        wires.len(),
        registry().len(),
        "registry grew: add the new policy to this parity test"
    );
    for w in &wires {
        parse_policy(w).expect(w);
    }
    wires
}

fn cond_for(family: &str) -> Cond {
    if family == "image" {
        Cond::Label(vec![3, 7])
    } else {
        Cond::Prompt(vec![1, 2, 3, 4, 5, 6, 7, 8, 11, 12, 13, 14, 15, 16, 17, 18])
    }
}

/// Drive a session by hand, checking the per-step surfaces along the
/// way, and return its output.
fn step_manually(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    plan: PlanRef<'_>,
    expected_batch: usize,
) -> smoothcache::pipeline::GenOutput {
    let mut session = GenSession::new(engine, cfg, cond, plan).expect("session");
    assert_eq!(session.total_steps(), cfg.steps);
    let mut computes = 0usize;
    let mut reuses = 0usize;
    while !session.is_done() {
        let before = session.current_step();
        let ev = session.step().expect("step");
        assert_eq!(ev.step, before);
        assert_eq!(ev.steps, cfg.steps);
        assert_eq!(session.current_step(), before + 1);
        assert_eq!(ev.done, session.is_done());
        computes += ev.computes;
        reuses += ev.reuses;
        // interim latent stays accessible mid-trajectory
        assert_eq!(session.latent().dim0(), expected_batch);
    }
    // events reconcile with the session's accumulated stats
    assert_eq!(computes, session.stats().branch_computes);
    assert_eq!(reuses, session.stats().branch_reuses);
    session.finish()
}

#[test]
fn driver_and_manual_stepping_agree_for_every_registry_policy() {
    let steps = 6usize;
    for family in ["image", "audio"] {
        let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
        engine.load_family(family).expect("family");
        let mut store = PlanStore::new(2, 7, None);
        for solver in [SolverKind::Ddim, SolverKind::RectifiedFlow] {
            for wire in registry_wires() {
                let policy = Policy::parse(wire).unwrap();
                let held;
                let plan = match policy.planner().dynamic() {
                    Some(sp) => PlanRef::Planner(sp),
                    None => {
                        held = store
                            .plan(&engine, None, family, solver, steps, &policy)
                            .expect(wire);
                        PlanRef::Plan(&held)
                    }
                };
                let cfg = GenConfig::new(family, solver, steps).with_seed(42);
                let cond = cond_for(family);
                let a = generate(&engine, &cfg, &cond, plan, None).expect(wire);
                let b = step_manually(&engine, &cfg, &cond, plan, 2);
                assert_eq!(
                    a.latent.data, b.latent.data,
                    "{family}/{}/{wire}: driver and manual stepping diverged",
                    solver.name()
                );
                assert_eq!(a.stats.branch_computes, b.stats.branch_computes);
                assert_eq!(a.stats.branch_reuses, b.stats.branch_reuses);
                assert_eq!(a.stats.steps, b.stats.steps);
                assert_eq!(a.stats.steps, steps);
            }
        }
    }
}

#[test]
fn parity_holds_under_cfg_guidance() {
    let steps = 5usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let policy = Policy::fora(2);
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &policy)
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps)
        .with_seed(9)
        .with_cfg(1.5);
    let cond = Cond::Label(vec![4]);
    let a = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
    let b = step_manually(&engine, &cfg, &cond, PlanRef::Plan(&plan), 1);
    assert_eq!(a.latent.data, b.latent.data, "CFG path diverged");
    assert_eq!(a.stats.branch_computes, b.stats.branch_computes);
}

#[test]
fn generate_from_matches_session_from_latent() {
    let steps = 4usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let policy = Policy::alternate();
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &policy)
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(5);
    let cond = Cond::Label(vec![1, 2]);
    let x0 = SolverRun::init_latent(vec![2, 16, 16, 4], &mut Rng::new(77));
    let a = generate_from(&engine, &cfg, &cond, x0.clone(), PlanRef::Plan(&plan), None).unwrap();
    let mut s = GenSession::from_latent(&engine, &cfg, &cond, x0, PlanRef::Plan(&plan)).unwrap();
    while !s.is_done() {
        s.step().unwrap();
    }
    let b = s.finish();
    assert_eq!(a.latent.data, b.latent.data);
}

#[test]
fn early_exit_returns_interim_latent_and_partial_stats() {
    let steps = 8usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let policy = Policy::no_cache();
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &policy)
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(1);
    let cond = Cond::Label(vec![0]);

    let mut s = GenSession::new(&engine, &cfg, &cond, PlanRef::Plan(&plan)).unwrap();
    for _ in 0..3 {
        s.step().unwrap();
    }
    let interim = s.latent().clone();
    let early = s.finish();
    assert_eq!(early.latent.data, interim.data, "finish must hand out the interim latent");
    assert_eq!(early.stats.steps, 3, "stats.steps records executed steps on early exit");

    // the abandoned trajectory differs from the completed one
    let full = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
    assert_eq!(full.stats.steps, steps);
    assert_ne!(full.latent.data, early.latent.data);
}

#[test]
fn session_rejects_stepping_past_the_end_and_empty_batches() {
    let steps = 2usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &Policy::no_cache())
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(1);

    let mut s =
        GenSession::new(&engine, &cfg, &Cond::Label(vec![0]), PlanRef::Plan(&plan)).unwrap();
    s.step().unwrap();
    s.step().unwrap();
    assert!(s.is_done());
    assert!(s.step().is_err(), "stepping past the end must error");

    let empty = Cond::Label(vec![]);
    assert!(GenSession::new(&engine, &cfg, &empty, PlanRef::Plan(&plan)).is_err());
}
