//! GenSession ↔ driver parity (ISSUE 5 acceptance): `generate` /
//! `generate_from` are thin drivers over the step-driven
//! [`GenSession`], and manual stepping must produce **bitwise**
//! identical latents and identical decision counters for every policy
//! in the registry, across two families × {ddim, rf} — plus the
//! session-only surfaces: per-step events that reconcile with the
//! final stats, interim latent access, and early exit.

use smoothcache::cache::plan::{parse_policy, registry, PlanRef};
use smoothcache::coordinator::{PlanStore, Policy};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, generate_from, GenConfig, GenSession};
use smoothcache::solvers::{SolverKind, SolverRun};
use smoothcache::util::rng::Rng;

/// One wire spelling per registry row (generous parameters so smooth /
/// drift actually skip on the untrained model). The length assertion
/// forces this list to grow with the registry.
fn registry_wires() -> Vec<&'static str> {
    let wires = vec![
        "no-cache",
        "fora:2",
        "alternate",
        "smooth:2.0",
        "smooth-persite:2.0",
        "delta-dit:2",
        "drift:1e9",
    ];
    assert_eq!(
        wires.len(),
        registry().len(),
        "registry grew: add the new policy to this parity test"
    );
    for w in &wires {
        parse_policy(w).expect(w);
    }
    wires
}

fn cond_for(family: &str) -> Cond {
    if family == "image" {
        Cond::Label(vec![3, 7])
    } else {
        Cond::Prompt(vec![1, 2, 3, 4, 5, 6, 7, 8, 11, 12, 13, 14, 15, 16, 17, 18])
    }
}

/// Drive a session by hand, checking the per-step surfaces along the
/// way, and return its output.
fn step_manually(
    engine: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    plan: PlanRef<'_>,
    expected_batch: usize,
) -> smoothcache::pipeline::GenOutput {
    let mut session = GenSession::new(engine, cfg, cond, plan).expect("session");
    assert_eq!(session.total_steps(), cfg.steps);
    let mut computes = 0usize;
    let mut reuses = 0usize;
    while !session.is_done() {
        let before = session.current_step();
        let ev = session.step().expect("step");
        assert_eq!(ev.step, before);
        assert_eq!(ev.steps, cfg.steps);
        assert_eq!(session.current_step(), before + 1);
        assert_eq!(ev.done, session.is_done());
        computes += ev.computes;
        reuses += ev.reuses;
        // interim latent stays accessible mid-trajectory
        assert_eq!(session.latent().dim0(), expected_batch);
    }
    // events reconcile with the session's accumulated stats
    assert_eq!(computes, session.stats().branch_computes);
    assert_eq!(reuses, session.stats().branch_reuses);
    session.finish()
}

#[test]
fn driver_and_manual_stepping_agree_for_every_registry_policy() {
    let steps = 6usize;
    for family in ["image", "audio"] {
        let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
        engine.load_family(family).expect("family");
        let mut store = PlanStore::new(2, 7, None);
        for solver in [SolverKind::Ddim, SolverKind::RectifiedFlow] {
            for wire in registry_wires() {
                let policy = Policy::parse(wire).unwrap();
                let held;
                let plan = match policy.planner().dynamic() {
                    Some(sp) => PlanRef::Planner(sp),
                    None => {
                        held = store
                            .plan(&engine, None, family, solver, steps, &policy)
                            .expect(wire);
                        PlanRef::Plan(&held)
                    }
                };
                let cfg = GenConfig::new(family, solver, steps).with_seed(42);
                let cond = cond_for(family);
                let a = generate(&engine, &cfg, &cond, plan, None).expect(wire);
                let b = step_manually(&engine, &cfg, &cond, plan, 2);
                assert_eq!(
                    a.latent.data, b.latent.data,
                    "{family}/{}/{wire}: driver and manual stepping diverged",
                    solver.name()
                );
                assert_eq!(a.stats.branch_computes, b.stats.branch_computes);
                assert_eq!(a.stats.branch_reuses, b.stats.branch_reuses);
                assert_eq!(a.stats.steps, b.stats.steps);
                assert_eq!(a.stats.steps, steps);
            }
        }
    }
}

#[test]
fn parity_holds_under_cfg_guidance() {
    let steps = 5usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let policy = Policy::fora(2);
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &policy)
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps)
        .with_seed(9)
        .with_cfg(1.5);
    let cond = Cond::Label(vec![4]);
    let a = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
    let b = step_manually(&engine, &cfg, &cond, PlanRef::Plan(&plan), 1);
    assert_eq!(a.latent.data, b.latent.data, "CFG path diverged");
    assert_eq!(a.stats.branch_computes, b.stats.branch_computes);
}

#[test]
fn generate_from_matches_session_from_latent() {
    let steps = 4usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let policy = Policy::alternate();
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &policy)
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(5);
    let cond = Cond::Label(vec![1, 2]);
    let x0 = SolverRun::init_latent(vec![2, 16, 16, 4], &mut Rng::new(77));
    let a = generate_from(&engine, &cfg, &cond, x0.clone(), PlanRef::Plan(&plan), None).unwrap();
    let mut s = GenSession::from_latent(&engine, &cfg, &cond, x0, PlanRef::Plan(&plan)).unwrap();
    while !s.is_done() {
        s.step().unwrap();
    }
    let b = s.finish();
    assert_eq!(a.latent.data, b.latent.data);
}

#[test]
fn early_exit_returns_interim_latent_and_partial_stats() {
    let steps = 8usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let policy = Policy::no_cache();
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &policy)
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(1);
    let cond = Cond::Label(vec![0]);

    let mut s = GenSession::new(&engine, &cfg, &cond, PlanRef::Plan(&plan)).unwrap();
    for _ in 0..3 {
        s.step().unwrap();
    }
    let interim = s.latent().clone();
    let early = s.finish();
    assert_eq!(early.latent.data, interim.data, "finish must hand out the interim latent");
    assert_eq!(early.stats.steps, 3, "stats.steps records executed steps on early exit");

    // the abandoned trajectory differs from the completed one
    let full = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None).unwrap();
    assert_eq!(full.stats.steps, steps);
    assert_ne!(full.latent.data, early.latent.data);
}

// ────────────── ISSUE 8: snapshot / resume round-trips ──────────────
//
// The preemptive scheduler (docs/adr/007) parks a session as an
// engine-independent [`SessionState`] and resumes it on whichever
// replica pops it next. These tests pin the seam the scheduler stands
// on: a snapshot taken at ANY step boundary, resumed on a DIFFERENT
// engine instance, continues to a bitwise-identical trajectory — for
// every registry policy (including `drift:*`, whose resume must carry
// the dynamic planner's feedback state), both solvers, and under CFG.

use smoothcache::pipeline::SessionState;

/// Run to step `k`, snapshot, resume the snapshot on `other`, finish.
fn run_with_park_at(
    origin: &Engine,
    other: &Engine,
    cfg: &GenConfig,
    cond: &Cond,
    plan: PlanRef<'_>,
    k: usize,
) -> smoothcache::pipeline::GenOutput {
    let mut first = GenSession::new(origin, cfg, cond, plan).expect("session");
    for _ in 0..k {
        first.step().expect("pre-park step");
    }
    let state: SessionState = first.snapshot();
    assert_eq!(state.step(), k);
    assert_eq!(state.total_steps(), cfg.steps);
    assert_eq!(state.is_done(), k == cfg.steps);
    drop(first); // the parked snapshot must not depend on the old session
    let mut resumed = GenSession::resume(other, state, plan).expect("resume");
    while !resumed.is_done() {
        resumed.step().expect("post-resume step");
    }
    resumed.finish()
}

#[test]
fn snapshot_resume_round_trip_is_bitwise_identical_at_every_boundary() {
    let steps = 6usize;
    let mut origin = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    origin.load_family("image").expect("family");
    // a genuinely different engine instance: own weight tables, own
    // scratch — the replica a parked session migrates to
    let mut other = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    other.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    for solver in [SolverKind::Ddim, SolverKind::RectifiedFlow] {
        for wire in registry_wires() {
            let policy = Policy::parse(wire).unwrap();
            let held;
            let plan = match policy.planner().dynamic() {
                Some(sp) => PlanRef::Planner(sp),
                None => {
                    held = store
                        .plan(&origin, None, "image", solver, steps, &policy)
                        .expect(wire);
                    PlanRef::Plan(&held)
                }
            };
            let cfg = GenConfig::new("image", solver, steps).with_seed(42);
            let cond = cond_for("image");
            let reference = generate(&origin, &cfg, &cond, plan, None).expect(wire);
            for k in 0..=steps {
                let out = run_with_park_at(&origin, &other, &cfg, &cond, plan, k);
                assert_eq!(
                    out.latent.data,
                    reference.latent.data,
                    "image/{}/{wire}: park at step {k} diverged",
                    solver.name()
                );
                assert_eq!(out.stats.branch_computes, reference.stats.branch_computes, "{wire}@{k}");
                assert_eq!(out.stats.branch_reuses, reference.stats.branch_reuses, "{wire}@{k}");
                assert_eq!(out.stats.steps, steps, "{wire}@{k}");
            }
        }
    }
}

/// Cross-family spot check (audio exercises the prompt-conditioned
/// path) at a mid-trajectory boundary.
#[test]
fn snapshot_resume_round_trip_holds_for_audio_family() {
    let steps = 4usize;
    let mut origin = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    origin.load_family("audio").expect("family");
    let mut other = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    other.load_family("audio").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    for wire in ["smooth:2.0", "drift:1e9"] {
        let policy = Policy::parse(wire).unwrap();
        let held;
        let plan = match policy.planner().dynamic() {
            Some(sp) => PlanRef::Planner(sp),
            None => {
                held = store
                    .plan(&origin, None, "audio", SolverKind::Ddim, steps, &policy)
                    .expect(wire);
                PlanRef::Plan(&held)
            }
        };
        let cfg = GenConfig::new("audio", SolverKind::Ddim, steps).with_seed(7);
        let cond = cond_for("audio");
        let reference = generate(&origin, &cfg, &cond, plan, None).expect(wire);
        let out = run_with_park_at(&origin, &other, &cfg, &cond, plan, steps / 2);
        assert_eq!(out.latent.data, reference.latent.data, "audio/{wire} diverged");
    }
}

/// CFG doubles the effective batch and adds the guidance mix; the
/// drift policy additionally threads per-site feedback state through
/// the snapshot. Both must survive a park at every boundary.
#[test]
fn snapshot_resume_round_trip_holds_under_cfg_including_drift_state() {
    let steps = 5usize;
    let mut origin = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    origin.load_family("image").expect("family");
    let mut other = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    other.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    for wire in ["smooth:2.0", "drift:1e9"] {
        let policy = Policy::parse(wire).unwrap();
        let held;
        let plan = match policy.planner().dynamic() {
            Some(sp) => PlanRef::Planner(sp),
            None => {
                held = store
                    .plan(&origin, None, "image", SolverKind::Ddim, steps, &policy)
                    .expect(wire);
                PlanRef::Plan(&held)
            }
        };
        let cfg = GenConfig::new("image", SolverKind::Ddim, steps)
            .with_seed(9)
            .with_cfg(1.5);
        let cond = Cond::Label(vec![4]);
        let reference = generate(&origin, &cfg, &cond, plan, None).expect(wire);
        for k in 0..=steps {
            let out = run_with_park_at(&origin, &other, &cfg, &cond, plan, k);
            assert_eq!(
                out.latent.data, reference.latent.data,
                "cfg/{wire}: park at step {k} diverged"
            );
            assert_eq!(out.stats.branch_reuses, reference.stats.branch_reuses, "{wire}@{k}");
        }
    }
}

/// Repeated preemption: park and migrate after EVERY step, bouncing
/// between two engine instances — the worst case the scheduler can
/// produce — and still land bitwise on the uninterrupted trajectory.
#[test]
fn chained_park_resume_after_every_step_stays_bitwise_identical() {
    let steps = 6usize;
    let mut origin = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    origin.load_family("image").expect("family");
    let mut other = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    other.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    for wire in ["no-cache", "smooth:2.0", "drift:1e9"] {
        let policy = Policy::parse(wire).unwrap();
        let held;
        let plan = match policy.planner().dynamic() {
            Some(sp) => PlanRef::Planner(sp),
            None => {
                held = store
                    .plan(&origin, None, "image", SolverKind::Ddim, steps, &policy)
                    .expect(wire);
                PlanRef::Plan(&held)
            }
        };
        let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(42);
        let cond = cond_for("image");
        let reference = generate(&origin, &cfg, &cond, plan, None).expect(wire);

        let engines = [&origin, &other];
        let mut state = GenSession::new(engines[0], &cfg, &cond, plan)
            .expect("session")
            .snapshot();
        let mut hops = 0usize;
        while !state.is_done() {
            let mut seg = GenSession::resume(engines[hops % 2], state, plan).expect("resume");
            hops += 1;
            seg.step().expect("step");
            state = seg.snapshot();
        }
        assert_eq!(hops, steps, "{wire}: one hop per step");
        let out = GenSession::resume(&origin, state, plan).expect("final resume").finish();
        assert_eq!(
            out.latent.data, reference.latent.data,
            "{wire}: {steps}-hop park/resume chain diverged"
        );
        assert_eq!(out.stats.branch_computes, reference.stats.branch_computes, "{wire}");
        assert_eq!(out.stats.steps, steps, "{wire}");
    }
}

/// Resume validation: a snapshot only resumes against a plan that
/// matches its geometry and kind — wrong step count and static↔dynamic
/// mismatches are rejected instead of silently corrupting the
/// trajectory.
#[test]
fn resume_rejects_mismatched_plans() {
    let steps = 4usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &Policy::no_cache())
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(1);
    let cond = Cond::Label(vec![0]);
    let mut s = GenSession::new(&engine, &cfg, &cond, PlanRef::Plan(&plan)).unwrap();
    s.step().unwrap();
    let state = s.snapshot();

    // wrong step count
    let short = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps - 1, &Policy::no_cache())
        .unwrap();
    assert!(
        GenSession::resume(&engine, state.clone(), PlanRef::Plan(&short)).is_err(),
        "a plan for a different step count must be rejected"
    );

    // static snapshot × dynamic planner
    let drift = Policy::parse("drift:1e9").unwrap();
    let sp = drift.planner().dynamic().expect("drift is dynamic");
    assert!(
        GenSession::resume(&engine, state.clone(), PlanRef::Planner(sp)).is_err(),
        "a static snapshot must not resume under a dynamic planner"
    );

    // the matching plan still works
    assert!(GenSession::resume(&engine, state, PlanRef::Plan(&plan)).is_ok());
}

#[test]
fn session_rejects_stepping_past_the_end_and_empty_batches() {
    let steps = 2usize;
    let mut engine = Engine::open(smoothcache::artifacts_dir()).expect("engine");
    engine.load_family("image").expect("family");
    let mut store = PlanStore::new(2, 7, None);
    let plan = store
        .plan(&engine, None, "image", SolverKind::Ddim, steps, &Policy::no_cache())
        .unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(1);

    let mut s =
        GenSession::new(&engine, &cfg, &Cond::Label(vec![0]), PlanRef::Plan(&plan)).unwrap();
    s.step().unwrap();
    s.step().unwrap();
    assert!(s.is_done());
    assert!(s.step().is_err(), "stepping past the end must error");

    let empty = Cond::Label(vec![]);
    assert!(GenSession::new(&engine, &cfg, &empty, PlanRef::Plan(&plan)).is_err());
}
