//! Serial/parallel parity suite (ISSUE 2): the threadpool GEMM +
//! attention path must match the serial path for every builtin family
//! at thread counts {1, 2, 8}; full `pipeline::generate` outputs must
//! be identical for a fixed seed across executor worker-pool sizes; and
//! `RuntimeStats` branch-execution counts for a cached schedule must be
//! invariant across thread/worker counts (caching decisions must never
//! depend on parallelism).
//!
//! The substrate's contract is actually stronger than the 1e-5 the
//! checks ask for — per-element f32 accumulation order is fixed, so the
//! results are bitwise identical — but the suite asserts the tolerance
//! the issue specifies plus bitwise equality where it is load-bearing.

use smoothcache::cache::{CachePlan, PlanRef, Schedule};
use smoothcache::coordinator::{Coordinator, CoordinatorConfig, Policy, Request};
use smoothcache::model::{Cond, Engine, Manifest};
use smoothcache::pipeline::{generate, GenConfig};
use smoothcache::solvers::SolverKind;
use smoothcache::tensor::gemm::Kernel;
use smoothcache::tensor::{gemm, Tensor};
use smoothcache::util::propcheck::{forall, gen};
use smoothcache::util::rng::Rng;

fn offline_engine(family: &str) -> Engine {
    let mut e = Engine::open(std::path::PathBuf::from("/nonexistent-artifacts"))
        .expect("builtin engine");
    e.load_family(family).expect("load family");
    e
}

/// A batch-2 latent + conditioning pair for any builtin family.
fn family_inputs(fm: &smoothcache::model::FamilyManifest) -> (Tensor, Cond) {
    let mut shape = vec![2usize];
    shape.extend(&fm.latent_shape);
    let mut rng = Rng::new(0xA11CE);
    let x = Tensor::randn(shape, &mut rng);
    let cond = if fm.num_classes > 0 {
        Cond::Label(vec![1, 4])
    } else {
        Cond::Prompt((0..2 * fm.cond_len).map(|i| (i * 7 % fm.vocab) as i32).collect())
    };
    (x, cond)
}

#[test]
fn forward_parity_across_thread_counts_for_every_family() {
    for (name, fm) in &Manifest::builtin().families {
        let engine = offline_engine(name);
        let (x, cond) = family_inputs(fm);
        let t = vec![0.4f32; 2];
        let serial = gemm::with_threads(1, || engine.forward(name, &x, &t, &cond, None))
            .expect("serial forward");
        for nt in [2usize, 8] {
            let parallel = gemm::with_threads(nt, || engine.forward(name, &x, &t, &cond, None))
                .expect("parallel forward");
            assert_eq!(serial.shape, parallel.shape, "{name} threads={nt}");
            let max_err = serial
                .data
                .iter()
                .zip(&parallel.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err <= 1e-5,
                "{name}: serial vs {nt}-thread forward diverged by {max_err}"
            );
            // the substrate actually guarantees bitwise equality
            assert_eq!(serial.data, parallel.data, "{name} threads={nt} not bitwise equal");
        }
    }
}

#[test]
fn branch_deltas_parity_across_thread_counts_for_every_family() {
    // per-branch-site check: this is the tensor the cache stores, so a
    // thread-dependent delta would poison reuse steps
    for (name, fm) in &Manifest::builtin().families {
        let engine = offline_engine(name);
        let (x, cond) = family_inputs(fm);
        let emb = engine.embed(name, &x, &[0.7, 0.7], &cond).expect("embed");
        let ctx = engine.make_step_ctx(&emb).expect("ctx");
        for br in &fm.branch_types {
            let serial = gemm::with_threads(1, || {
                engine.branch(name, 0, br, &emb.tokens, &ctx)
            })
            .expect("serial branch");
            for nt in [2usize, 8] {
                let parallel = gemm::with_threads(nt, || {
                    engine.branch(name, 0, br, &emb.tokens, &ctx)
                })
                .expect("parallel branch");
                assert_eq!(serial, parallel, "{name}.{br} threads={nt}");
            }
        }
    }
}

#[test]
fn generate_is_identical_across_thread_counts_for_every_family() {
    for (name, fm) in &Manifest::builtin().families {
        let engine = offline_engine(name);
        let (_, cond) = family_inputs(fm);
        let schedule = Schedule::fora(3, &fm.branch_types, 2);
        let plan = CachePlan::from_grouped(&schedule, &fm.branch_sites()).unwrap();
        let cfg = GenConfig::new(name, SolverKind::Ddim, 3).with_seed(42);
        let base = gemm::with_threads(1, || {
            generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None)
        })
        .expect("serial generate");
        for nt in [2usize, 8] {
            let out = gemm::with_threads(nt, || {
                generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None)
            })
            .expect("parallel generate");
            assert_eq!(base.latent, out.latent, "{name} threads={nt}");
            assert_eq!(base.stats.branch_computes, out.stats.branch_computes);
            assert_eq!(base.stats.branch_reuses, out.stats.branch_reuses);
        }
    }
}

#[test]
fn generate_is_identical_across_kernels_for_every_family_and_solver() {
    // the SIMD microkernel keeps the scalar reference's per-element
    // accumulation order, so a full trajectory must come out bitwise
    // identical whichever kernel dispatch picks — for every builtin
    // family and every solver
    let solvers = [
        SolverKind::Ddim,
        SolverKind::DdpmAncestral,
        SolverKind::DpmPP2M,
        SolverKind::DpmPP3M { sde: false },
        SolverKind::DpmPP3M { sde: true },
        SolverKind::RectifiedFlow,
    ];
    for (name, fm) in &Manifest::builtin().families {
        let engine = offline_engine(name);
        let (_, cond) = family_inputs(fm);
        let schedule = Schedule::fora(3, &fm.branch_types, 2);
        let plan = CachePlan::from_grouped(&schedule, &fm.branch_sites()).unwrap();
        for solver in solvers {
            let cfg = GenConfig::new(name, solver, 3).with_seed(77);
            let scalar = gemm::with_kernel(Kernel::Scalar, || {
                generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None)
            })
            .expect("scalar generate");
            let auto = gemm::with_kernel(Kernel::Auto, || {
                generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None)
            })
            .expect("auto generate");
            assert_eq!(
                scalar.latent,
                auto.latent,
                "{name}/{}: scalar vs auto kernel diverged",
                solver.name()
            );
            assert_eq!(scalar.stats.branch_computes, auto.stats.branch_computes);
            assert_eq!(scalar.stats.branch_reuses, auto.stats.branch_reuses);
        }
    }
}

#[test]
fn prop_simd_scalar_matmul_parity_on_adversarial_shapes() {
    // shape corners the tiled microkernel must get right: single-row
    // panels (m = 1), k below one cache block (k < KC), and column
    // counts that are never a SIMD lane multiple (odd n), plus k
    // straddling a KC boundary
    forall(
        0x51D0,
        40,
        |r: &mut Rng| {
            let m = if r.below(3) == 0 { 1 } else { gen::usize_in(r, 1, 9) };
            let k = if r.below(2) == 0 {
                gen::usize_in(r, 1, gemm::KC) // strictly below one k-block
            } else {
                gen::usize_in(r, gemm::KC, gemm::KC + 70)
            };
            let n = 2 * gen::usize_in(r, 0, 40) + 1; // odd: off every lane width
            (m, k, n)
        },
        |&(m, k, n)| {
            let mut rng = Rng::new((m * 1_000_003 + k * 1_009 + n) as u64);
            let x = rng.normal_vec(m * k);
            let w = rng.normal_vec(k * n);
            let bias = rng.normal_vec(n);
            let scalar =
                gemm::with_kernel(Kernel::Scalar, || gemm::matmul(&x, m, k, &w, n, Some(&bias)));
            let auto =
                gemm::with_kernel(Kernel::Auto, || gemm::matmul(&x, m, k, &w, n, Some(&bias)));
            if scalar != auto {
                return Err(format!("matmul: scalar != auto at {m}x{k}x{n}"));
            }
            let naive = gemm::matmul_naive(&x, m, k, &w, n, Some(&bias));
            if scalar != naive {
                return Err(format!("matmul: scalar != naive at {m}x{k}x{n}"));
            }
            let wt = rng.normal_vec(n * k);
            let sbt = gemm::with_kernel(Kernel::Scalar, || {
                gemm::matmul_bt(&x, m, k, &wt, n, Some(&bias))
            });
            let abt = gemm::with_kernel(Kernel::Auto, || {
                gemm::matmul_bt(&x, m, k, &wt, n, Some(&bias))
            });
            if sbt != abt {
                return Err(format!("matmul_bt: scalar != auto at {m}x{k}x{n}"));
            }
            Ok(())
        },
    );
}

#[test]
fn generate_is_identical_across_worker_pool_sizes() {
    // the same (seed, request) served by coordinators with 1, 2, and 3
    // executor replicas must produce bitwise-identical latents and
    // identical cache behaviour
    let request = || Request {
        id: 0,
        family: "image".into(),
        cond: Cond::Label(vec![5]),
        solver: SolverKind::Ddim,
        steps: 4,
        cfg_scale: 1.0,
        seed: 0xF1DE,
        policy: Policy::fora(2),
        compute: Default::default(),
        priority: Default::default(),
    };
    let mut outputs = Vec::new();
    for workers in [1usize, 2, 3] {
        let cfg = CoordinatorConfig::new(smoothcache::artifacts_dir()).with_workers(workers);
        let coord = Coordinator::start(cfg).expect("coordinator");
        let resp = coord.generate_blocking(request()).expect("response");
        outputs.push((workers, resp.latent, resp.gen_stats));
        coord.shutdown();
    }
    let (_, base_latent, base_stats) = &outputs[0];
    for (workers, latent, stats) in &outputs[1..] {
        assert_eq!(
            base_latent, latent,
            "worker-pool size {workers} changed the generated latent"
        );
        assert_eq!(base_stats.branch_computes, stats.branch_computes, "workers={workers}");
        assert_eq!(base_stats.branch_reuses, stats.branch_reuses, "workers={workers}");
    }
}

#[test]
fn runtime_stats_invariant_across_thread_counts_for_cached_schedule() {
    // perf-counter regression (ISSUE 2 satellite): branch-execution
    // counts under a cached schedule must not depend on the GEMM
    // thread count
    let engine = offline_engine("image");
    let fm = engine.family_manifest("image").expect("manifest").clone();
    let schedule = Schedule::fora(6, &fm.branch_types, 2);
    let plan = CachePlan::from_grouped(&schedule, &fm.branch_sites()).unwrap();
    let cfg = GenConfig::new("image", SolverKind::Ddim, 6).with_seed(9);
    let cond = Cond::Label(vec![2]);
    let mut observed = Vec::new();
    for nt in [1usize, 2, 8] {
        engine.reset_stats();
        let out = gemm::with_threads(nt, || {
            generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None)
        })
        .expect("generate");
        let st = engine.stats();
        observed.push((nt, st.executions, out.stats.branch_computes, out.stats.branch_reuses));
    }
    let (_, base_exec, base_computes, base_reuses) = observed[0];
    assert!(base_reuses > 0, "fora:2 must produce reuses");
    for &(nt, execs, computes, reuses) in &observed[1..] {
        assert_eq!(execs, base_exec, "backend executions changed at threads={nt}");
        assert_eq!(computes, base_computes, "branch computes changed at threads={nt}");
        assert_eq!(reuses, base_reuses, "branch reuses changed at threads={nt}");
    }
}
