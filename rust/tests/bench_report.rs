//! Tests for the machine-readable bench-report layer (ISSUE 6):
//! round-trip through `util::json` (including a propcheck sweep over
//! random metric sets), loud NaN/inf rejection, `diff` threshold
//! semantics (symmetric tolerance, direction awareness, missing-metric
//! = hard error), and the `bench_diff` binary's exit codes — pinned
//! here: a synthetically injected >10% throughput regression makes it
//! exit non-zero (the PR's acceptance criterion).

use std::process::Command;

use smoothcache::util::bench::report::{diff, BenchReport, DiffStatus, Metric, SCHEMA};
use smoothcache::util::json::parse;
use smoothcache::util::propcheck::{forall, gen};

fn tmp_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("smoothcache_bench_report_{}_{tag}.json", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

fn sample_report() -> BenchReport {
    let mut r = BenchReport::new("serving");
    r.meta("family", "image");
    r.meta("steps", 2);
    r.metric("no-cache/throughput_rps", 100.0, "req/s", true).unwrap();
    r.metric_tol("fora:2/p95_s", 0.5, "s", false, 60.0).unwrap();
    r
}

// ---------------------------------------------------------------------------
// round-trip + validation
// ---------------------------------------------------------------------------

#[test]
fn report_roundtrips_through_util_json() {
    let r = sample_report();
    let text = r.to_json().to_string_pretty();
    let back = BenchReport::from_json(&parse(&text).unwrap()).unwrap();
    assert_eq!(back, r);
    assert!(text.contains(SCHEMA));
}

#[test]
fn report_roundtrip_property_over_random_metric_sets() {
    // names come from the index (unique by construction); direction and
    // tolerance derive from the index so the whole surface is exercised
    forall(
        0xBE7C4,
        60,
        |rng| {
            gen::vec_of(rng, 0, 24, |rng| {
                (gen::usize_in(rng, 0, 4), gen::f64_in(rng, -1e9, 1e9))
            })
        },
        |metrics: &Vec<(usize, f64)>| {
            let mut r = BenchReport::new("prop");
            r.meta("smoke", true);
            for (i, (kind, value)) in metrics.iter().enumerate() {
                let m = Metric {
                    name: format!("scope{kind}/metric{i}"),
                    value: *value,
                    unit: ["us", "req/s", "%", "x"][*kind % 4].to_string(),
                    higher_is_better: i % 2 == 0,
                    tol_pct: (kind % 2 == 0).then_some((i as f64) * 3.5),
                };
                r.push(m).map_err(|e| format!("push: {e}"))?;
            }
            let back = BenchReport::from_json(&parse(&r.to_json().to_string()).unwrap())
                .map_err(|e| format!("from_json: {e}"))?;
            if back != r {
                return Err("round-trip mismatch".into());
            }
            // self-diff is always a clean gate
            let d = diff(&r, &r, 10.0);
            if !d.gate_ok() {
                return Err(format!("self-diff failed the gate: {}", d.summary()));
            }
            Ok(())
        },
    );
}

#[test]
fn nan_and_inf_are_rejected_loudly() {
    let mut r = BenchReport::new("t");
    for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY] {
        let e = r.metric("m", bad, "u", true).unwrap_err();
        assert!(e.to_string().contains("non-finite"), "{e}");
    }
    // a NaN smuggled past push (public fields) is caught at save time
    let mut r2 = sample_report();
    r2.metrics[0].value = f64::NAN;
    assert!(r2.save(&tmp_path("nan")).is_err());
    // and a null value in a file is rejected at load, not zeroed
    let path = tmp_path("null_value");
    std::fs::write(
        &path,
        format!(
            "{{\"schema\": \"{SCHEMA}\", \"area\": \"t\", \"metrics\": \
             [{{\"name\": \"m\", \"value\": null, \"unit\": \"u\", \"higher_is_better\": true}}]}}"
        ),
    )
    .unwrap();
    let e = BenchReport::load(&path).unwrap_err();
    assert!(e.to_string().contains("finite"), "{e}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn wrong_schema_tag_is_rejected() {
    let j = smoothcache::util::json::Json::obj()
        .set("schema", "something/else")
        .set("area", "t")
        .set("metrics", smoothcache::util::json::Json::Arr(vec![]));
    assert!(BenchReport::from_json(&j).is_err());
}

// ---------------------------------------------------------------------------
// diff semantics
// ---------------------------------------------------------------------------

fn one_metric(value: f64, higher_is_better: bool) -> BenchReport {
    let mut r = BenchReport::new("t");
    r.metric("m", value, "u", higher_is_better).unwrap();
    r
}

#[test]
fn diff_tolerance_is_symmetric_and_direction_aware() {
    // within ±10% nothing moves the gate, in either direction
    for (base, cand) in [(100.0, 95.0), (100.0, 105.0)] {
        for hib in [true, false] {
            let d = diff(&one_metric(base, hib), &one_metric(cand, hib), 10.0);
            assert_eq!(d.rows[0].status, DiffStatus::Unchanged, "base={base} cand={cand} hib={hib}");
        }
    }
    // beyond tolerance: worse direction regresses, better improves
    let d = diff(&one_metric(100.0, true), &one_metric(80.0, true), 10.0);
    assert_eq!(d.rows[0].status, DiffStatus::Regressed);
    let d = diff(&one_metric(100.0, true), &one_metric(120.0, true), 10.0);
    assert_eq!(d.rows[0].status, DiffStatus::Improved);
    let d = diff(&one_metric(100.0, false), &one_metric(120.0, false), 10.0);
    assert_eq!(d.rows[0].status, DiffStatus::Regressed);
    let d = diff(&one_metric(100.0, false), &one_metric(80.0, false), 10.0);
    assert_eq!(d.rows[0].status, DiffStatus::Improved);
}

#[test]
fn diff_missing_metric_is_a_hard_error_not_a_silent_pass() {
    let base = sample_report();
    let mut cand = BenchReport::new("serving");
    cand.metric("no-cache/throughput_rps", 100.0, "req/s", true).unwrap();
    // "fora:2/p95_s" dropped from the candidate
    let d = diff(&base, &cand, 10.0);
    assert_eq!(d.hard_errors(), 1);
    assert!(!d.gate_ok());
    assert!(d
        .rows
        .iter()
        .any(|r| r.name == "fora:2/p95_s" && r.status == DiffStatus::Missing));
}

#[test]
fn diff_baseline_tolerance_is_authoritative() {
    let mut base = BenchReport::new("t");
    base.metric_tol("m", 100.0, "u", true, 50.0).unwrap();
    // candidate carries a *tighter* tolerance, but the baseline's wins
    let mut cand = BenchReport::new("t");
    cand.metric_tol("m", 60.0, "u", true, 1.0).unwrap();
    let d = diff(&base, &cand, 10.0);
    assert_eq!(d.rows[0].status, DiffStatus::Unchanged);
    assert!((d.rows[0].tol_pct - 50.0).abs() < 1e-12);
}

// ---------------------------------------------------------------------------
// bench_diff binary (exit codes; the injected-regression acceptance pin)
// ---------------------------------------------------------------------------

fn run_bench_diff(args: &[&str]) -> (i32, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_bench_diff"))
        .args(args)
        .output()
        .expect("spawn bench_diff");
    let stdout = String::from_utf8_lossy(&out.stdout).into_owned();
    (out.status.code().unwrap_or(-1), stdout)
}

#[test]
fn bench_diff_passes_identical_reports() {
    let path = tmp_path("identical");
    sample_report().save(&path).unwrap();
    let (code, stdout) = run_bench_diff(&[&path, &path]);
    assert_eq!(code, 0, "stdout:\n{stdout}");
    assert!(stdout.contains("gate: OK"), "{stdout}");
    let _ = std::fs::remove_file(&path);
}

#[test]
fn bench_diff_flags_injected_throughput_regression() {
    // the PR acceptance pin: a synthetic >10% throughput drop must make
    // bench_diff exit non-zero
    let base_path = tmp_path("regress_base");
    let cand_path = tmp_path("regress_cand");
    sample_report().save(&base_path).unwrap();
    let mut cand = sample_report();
    cand.metrics[0].value = 85.0; // throughput 100 → 85: a 15% drop
    cand.save(&cand_path).unwrap();
    let (code, stdout) = run_bench_diff(&[&base_path, &cand_path]);
    assert_eq!(code, 1, "expected regression exit code, stdout:\n{stdout}");
    assert!(stdout.contains("REGRESSED"), "{stdout}");
    assert!(stdout.contains("gate: FAIL"), "{stdout}");

    // the same drop passes under a caller-widened default tolerance
    let (code, stdout) = run_bench_diff(&[&base_path, &cand_path, "--tol", "30"]);
    assert_eq!(code, 0, "stdout:\n{stdout}");
    let _ = std::fs::remove_file(&base_path);
    let _ = std::fs::remove_file(&cand_path);
}

#[test]
fn bench_diff_missing_metric_and_bad_usage_exit_2() {
    let base_path = tmp_path("hard_base");
    let cand_path = tmp_path("hard_cand");
    sample_report().save(&base_path).unwrap();
    let mut cand = BenchReport::new("serving");
    cand.metric("no-cache/throughput_rps", 100.0, "req/s", true).unwrap();
    cand.save(&cand_path).unwrap();
    let (code, _) = run_bench_diff(&[&base_path, &cand_path]);
    assert_eq!(code, 2);
    // usage errors are also structural failures
    let (code, _) = run_bench_diff(&[&base_path]);
    assert_eq!(code, 2);
    let (code, _) = run_bench_diff(&[&base_path, &cand_path, "--typo"]);
    assert_eq!(code, 2);
    let (code, _) = run_bench_diff(&["/definitely/not/here.json", &cand_path]);
    assert_eq!(code, 2);
    let _ = std::fs::remove_file(&base_path);
    let _ = std::fs::remove_file(&cand_path);
}
