//! End-to-end serving driver (the repo's headline validation run):
//! starts the full coordinator + TCP server on the trained image DiT,
//! replays an open-loop Poisson trace through a real socket client, and
//! reports throughput / latency percentiles / batch occupancy with
//! SmoothCache on vs off. Results are recorded in EXPERIMENTS.md.
//!
//!     cargo run --release --example serve_e2e -- --requests 32 --rate 4

use std::sync::Arc;
use std::time::{Duration, Instant};

use smoothcache::coordinator::{Coordinator, CoordinatorConfig};
use smoothcache::server::{Client, Server};
use smoothcache::util::bench::Table;
use smoothcache::util::cli::CliSpec;
use smoothcache::util::json::Json;
use smoothcache::workload::PoissonTrace;

fn main() -> smoothcache::util::error::Result<()> {
    let spec = CliSpec::new("serve_e2e", "end-to-end serving driver")
        .flag("requests", "32", "requests per policy")
        .flag("rate", "4.0", "Poisson arrival rate (req/s)")
        .flag("steps", "50", "DDIM steps")
        .flag("policies", "no-cache,fora:2,smooth:0.35,drift:0.35", "policies to compare")
        .flag("calib-samples", "6", "calibration samples for smooth policies");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match spec.parse(&argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return Ok(());
        }
    };
    let n_requests = args.usize("requests").map_err(smoothcache::util::error::Error::msg)?;
    let rate = args.f64("rate").map_err(smoothcache::util::error::Error::msg)?;
    let steps = args.usize("steps").map_err(smoothcache::util::error::Error::msg)?;
    let policies = args.list("policies");

    let mut table = Table::new(&[
        "policy", "throughput (req/s)", "p50 (s)", "p95 (s)", "occupancy", "skip%",
    ]);

    for policy in &policies {
        let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
        cfg.preload = vec!["image".into()];
        cfg.max_wait = Duration::from_millis(25);
        cfg.calib_samples = args.usize("calib-samples").map_err(smoothcache::util::error::Error::msg)?;
        let coord = Arc::new(Coordinator::start(cfg)?);
        let server = Server::start("127.0.0.1:0", Arc::clone(&coord), 4)?;
        println!("serving on {} — policy {policy}", server.addr);
        let mut client = Client::connect(&server.addr)?;

        let mk_req = |label: i32, seed: u64| {
            Json::obj()
                .set("family", "image")
                .set("label", label as f64)
                .set("steps", steps)
                .set("solver", "ddim")
                .set("policy", policy.as_str())
                .set("seed", seed)
        };
        // warmup: compile + calibrate outside the measured window
        for b in 0..3 {
            let r = client.call(&mk_req(b, 50 + b as u64))?;
            smoothcache::ensure!(
                r.get("ok").and_then(|v| v.as_bool()) == Some(true),
                "warmup failed: {r:?}"
            );
        }

        let trace = PoissonTrace::generate(rate, n_requests, 10, 0, 0, 0xE2E);
        // open-loop over the socket: issue at trace times from worker
        // threads (each with its own connection), gather latencies.
        let t0 = Instant::now();
        let pool = smoothcache::util::threadpool::ThreadPool::new(8);
        let addr = server.addr;
        let results: Vec<(f64, f64)> = pool.parallel_map(
            trace.items.iter().enumerate().map(|(i, it)| {
                let label = match &it.cond {
                    smoothcache::model::Cond::Label(l) => l[0],
                    _ => 0,
                };
                (i, it.arrival_s, label, it.seed, policy.clone())
            }).collect::<Vec<_>>(),
            move |(i, arrival, label, seed, policy)| {
                let target = t0 + Duration::from_secs_f64(arrival);
                if let Some(d) = target.checked_duration_since(Instant::now()) {
                    std::thread::sleep(d);
                }
                let mut c = Client::connect(&addr).expect("connect");
                let req = Json::obj()
                    .set("family", "image")
                    .set("label", label as f64)
                    .set("steps", steps)
                    .set("solver", "ddim")
                    .set("policy", policy.as_str())
                    .set("seed", seed ^ i as u64);
                let sent = Instant::now();
                let r = c.call(&req).expect("call");
                assert_eq!(r.get("ok").and_then(|v| v.as_bool()), Some(true), "{r:?}");
                (
                    sent.elapsed().as_secs_f64(),
                    r.get("skip_fraction").and_then(|v| v.as_f64()).unwrap_or(0.0),
                )
            },
        );
        let wall = t0.elapsed().as_secs_f64();
        let mut lats: Vec<f64> = results.iter().map(|r| r.0).collect();
        lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pct =
            |q: f64| lats[((q * (lats.len() - 1) as f64) as usize).min(lats.len() - 1)];
        let skip = results.last().map(|r| r.1).unwrap_or(0.0);
        println!("coordinator metrics: {}", coord.metrics().summary());
        table.row(&[
            policy.clone(),
            format!("{:.2}", n_requests as f64 / wall),
            format!("{:.3}", pct(0.5)),
            format!("{:.3}", pct(0.95)),
            format!("{:.2}", coord.metrics().occupancy()),
            format!("{:.0}%", skip * 100.0),
        ]);
        server.stop();
    }

    println!("\nserve_e2e — image DDIM-{steps}, {n_requests} requests @ {rate} req/s");
    table.print();
    Ok(())
}
