//! Fig. 1 style demo: accelerate all three modalities with one
//! technique. Generates an image (DDIM-50), an audio clip
//! (DPM++(3M)-SDE-100) and a video (RF-30) with SmoothCache on and off,
//! writing PGM/CSV renders plus a per-modality speedup summary.
//!
//!     cargo run --release --example multimodal_generate

use smoothcache::cache::{calibrate, paper_protocol, CachePlan, PlanRef};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, GenConfig};
use smoothcache::quality::psnr;
use smoothcache::util::bench::Table;

fn write_pgm(path: &str, data: &[f32], h: usize, w: usize) -> std::io::Result<()> {
    let lo = data.iter().cloned().fold(f32::MAX, f32::min);
    let hi = data.iter().cloned().fold(f32::MIN, f32::max);
    let span = (hi - lo).max(1e-9);
    let mut out = format!("P2\n{w} {h}\n255\n");
    for y in 0..h {
        for x in 0..w {
            out.push_str(&format!("{} ", ((data[y * w + x] - lo) / span * 255.0) as u32));
        }
        out.push('\n');
    }
    std::fs::write(path, out)
}

fn main() -> smoothcache::util::error::Result<()> {
    let out_dir = "bench_out/multimodal";
    std::fs::create_dir_all(out_dir)?;
    let mut engine = Engine::open(smoothcache::artifacts_dir())?;
    let mut table =
        Table::new(&["modality", "solver", "steps", "alpha", "speedup", "PSNR vs no-cache"]);

    for family in ["image", "audio", "video"] {
        engine.load_family(family)?;
        let fm = engine.family_manifest(family)?.clone();
        let mut cc = paper_protocol(family);
        cc.num_samples = 4; // quick demo calibration
        let curves = calibrate(&engine, family, &cc)?;
        let (alpha, schedule) = curves.alpha_for_skip_fraction(0.35, &fm.branch_types);

        let cond = if fm.num_classes > 0 {
            Cond::Label(vec![3])
        } else {
            Cond::Prompt((5..5 + fm.cond_len as i32).collect())
        };
        let cfg = GenConfig::new(family, cc.solver, cc.steps)
            .with_cfg(if family == "image" { 1.0 } else { 7.0 })
            .with_seed(11);

        let sites = fm.branch_sites();
        let no_cache = CachePlan::no_cache(cc.steps, &sites);
        let plan = CachePlan::from_grouped(&schedule, &sites)?;
        let base = generate(&engine, &cfg, &cond, PlanRef::Plan(&no_cache), None)?;
        let fast = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None)?;

        match family {
            "image" => {
                let plane: Vec<f32> = (0..256).map(|i| fast.latent.data[i * 4]).collect();
                write_pgm(&format!("{out_dir}/image_smoothcache.pgm"), &plane, 16, 16)?;
            }
            "audio" => {
                let mut csv = String::new();
                for t in 0..64 {
                    let row: Vec<String> = (0..8)
                        .map(|c| format!("{:.4}", fast.latent.data[t * 8 + c]))
                        .collect();
                    csv.push_str(&row.join(","));
                    csv.push('\n');
                }
                std::fs::write(format!("{out_dir}/audio_smoothcache.csv"), csv)?;
            }
            _ => {
                let plane: Vec<f32> = (0..64).map(|i| fast.latent.data[i * 4]).collect();
                write_pgm(&format!("{out_dir}/video_frame0_smoothcache.pgm"), &plane, 8, 8)?;
            }
        }

        table.row(&[
            family.into(),
            cc.solver.name().into(),
            cc.steps.to_string(),
            format!("{alpha:.3}"),
            format!("{:.2}x", base.stats.wall_seconds / fast.stats.wall_seconds),
            format!("{:.1} dB", psnr(&base.latent, &fast.latent)),
        ]);
        println!("[{family}] done");
    }

    println!("\nFig. 1 — one technique, three modalities (outputs in {out_dir}/)");
    table.print();
    Ok(())
}
