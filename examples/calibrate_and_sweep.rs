//! Calibrate any family/solver/steps configuration, print the error
//! curves and an alpha sweep, and save the curves + schedules as JSON
//! (consumable by the server's `--curves-dir`).
//!
//!     cargo run --release --example calibrate_and_sweep -- \
//!         --family audio --solver dpmpp3m-sde --steps 100 --samples 10

use smoothcache::cache::{calibrate, CalibrationConfig};
use smoothcache::model::Engine;
use smoothcache::solvers::SolverKind;
use smoothcache::util::bench::{ascii_plot, Table};
use smoothcache::util::cli::CliSpec;

fn main() -> smoothcache::util::error::Result<()> {
    let spec = CliSpec::new("calibrate_and_sweep", "calibration + alpha sweep")
        .flag("family", "image", "model family (image|audio|video)")
        .flag("solver", "ddim", "solver (ddim|ddpm|dpmpp2m|dpmpp3m|dpmpp3m-sde|rf)")
        .flag("steps", "50", "sampling steps")
        .flag("samples", "10", "calibration samples")
        .flag("k-max", "3", "maximum reuse gap")
        .flag("cfg", "1.0", "CFG scale during calibration")
        .flag("alphas", "0.05,0.1,0.2,0.35,0.5,0.8", "alpha sweep")
        .flag("out", "bench_out/calibration", "output directory");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match spec.parse(&argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return Ok(());
        }
    };

    let family = args.string("family");
    let solver = SolverKind::parse(args.str("solver"))
        .ok_or_else(|| smoothcache::err!("bad solver"))?;
    let steps = args.usize("steps").map_err(smoothcache::util::error::Error::msg)?;

    let mut engine = Engine::open(smoothcache::artifacts_dir())?;
    engine.load_family(&family)?;
    let fm = engine.family_manifest(&family)?.clone();

    let cc = CalibrationConfig {
        solver,
        steps,
        k_max: args.usize("k-max").map_err(smoothcache::util::error::Error::msg)?,
        num_samples: args.usize("samples").map_err(smoothcache::util::error::Error::msg)?,
        cfg_scale: args.f64("cfg").map_err(smoothcache::util::error::Error::msg)? as f32,
        seed: 7,
    };
    println!(
        "calibrating {family} / {} / {steps} steps / {} samples ...",
        solver.name(),
        cc.num_samples
    );
    let t0 = std::time::Instant::now();
    let curves = calibrate(&engine, &family, &cc)?;
    println!("calibration took {:.1}s (one-time cost)\n", t0.elapsed().as_secs_f64());

    // error-curve plot (k=1)
    let series: Vec<(String, Vec<f64>)> = curves
        .branch_types()
        .into_iter()
        .map(|bt| {
            let ys = (1..steps).map(|s| curves.mean(&bt, s, 1).unwrap_or(0.0)).collect();
            (bt, ys)
        })
        .collect();
    println!("{}", ascii_plot("L1 relative error (k=1) across steps", &series, 12));

    // alpha sweep
    let mut table = Table::new(&["alpha", "skip%", "max gap", "schedule"]);
    for alpha in args.f64_list("alphas").map_err(smoothcache::util::error::Error::msg)? {
        let s = curves.smoothcache_schedule(alpha, &fm.branch_types);
        let compact: String = s
            .ascii()
            .lines()
            .map(|l| l.chars().skip(11).collect::<String>())
            .collect::<Vec<_>>()
            .join(" | ");
        table.row(&[
            format!("{alpha}"),
            format!("{:.0}%", s.skip_fraction() * 100.0),
            s.max_gap().to_string(),
            compact.chars().take(70).collect(),
        ]);
    }
    table.print();

    // persist
    let out = args.string("out");
    std::fs::create_dir_all(&out)?;
    let path = format!("{out}/{family}_{}_{steps}.json", solver.name());
    std::fs::write(&path, curves.to_json().to_string())?;
    println!("\ncurves saved to {path} (usable via server --curves-dir)");
    Ok(())
}
