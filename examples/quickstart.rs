//! Quickstart: calibrate SmoothCache on the bundled image DiT, generate
//! with and without caching, and compare speed + output drift.
//!
//!     make artifacts && cargo run --release --example quickstart

use smoothcache::cache::{calibrate, CachePlan, CalibrationConfig, PlanRef};
use smoothcache::model::{Cond, Engine};
use smoothcache::pipeline::{generate, GenConfig};
use smoothcache::quality::psnr;
use smoothcache::solvers::SolverKind;

fn main() -> smoothcache::util::error::Result<()> {
    let dir = smoothcache::artifacts_dir();
    println!("artifacts: {dir:?}");
    let mut engine = Engine::open(dir)?;
    engine.load_family("image")?;
    println!(
        "loaded image family ({} parameters) on {}",
        engine.total_params("image").unwrap(),
        engine.platform()
    );

    // 1. One calibration pass (the paper's single hyperparameter setup).
    let steps = 30;
    let cc = CalibrationConfig {
        num_samples: 4,
        ..CalibrationConfig::new(SolverKind::Ddim, steps)
    };
    println!("calibrating DDIM-{steps} with {} samples ...", cc.num_samples);
    let curves = calibrate(&engine, "image", &cc)?;

    // 2. Threshold the error curves at alpha to get a static schedule.
    let alpha = 0.35;
    let fm = engine.family_manifest("image")?.clone();
    let schedule = curves.smoothcache_schedule(alpha, &fm.branch_types);
    println!("\nSmoothCache schedule at alpha={alpha} (#=compute, .=reuse):");
    print!("{}", schedule.ascii());
    println!("skip fraction: {:.0}%\n", schedule.skip_fraction() * 100.0);

    // 3. Generate the same sample with and without the cache.
    let cond = Cond::Label(vec![7]);
    let cfg = GenConfig::new("image", SolverKind::Ddim, steps).with_seed(42);
    let sites = fm.branch_sites();
    let no_cache = CachePlan::no_cache(steps, &sites);
    let plan = CachePlan::from_grouped(&schedule, &sites)?;
    let base = generate(&engine, &cfg, &cond, PlanRef::Plan(&no_cache), None)?;
    let cached = generate(&engine, &cfg, &cond, PlanRef::Plan(&plan), None)?;

    println!(
        "no-cache : {:.3}s ({} branch executions)",
        base.stats.wall_seconds, base.stats.branch_computes
    );
    println!(
        "cached   : {:.3}s ({} executed, {} reused)",
        cached.stats.wall_seconds, cached.stats.branch_computes, cached.stats.branch_reuses
    );
    println!(
        "speedup  : {:.2}x    output PSNR vs no-cache: {:.1} dB",
        base.stats.wall_seconds / cached.stats.wall_seconds,
        psnr(&base.latent, &cached.latent)
    );
    Ok(())
}
