//! Streaming + cancellation demo: start the serving stack, run one
//! generation in streaming mode (one `{"event":"step",…}` line per
//! solver step over the socket), then start a second long generation
//! and cancel it mid-flight by id from a sibling connection — the
//! executor stops at the next solver step and the admission slot
//! frees (docs/protocol.md §Streaming, §Cancellation).
//!
//!     cargo run --release --example stream_cancel -- --steps 40

use std::sync::Arc;
use std::time::Duration;

use smoothcache::coordinator::{Coordinator, CoordinatorConfig};
use smoothcache::server::{Client, Server};
use smoothcache::util::cli::CliSpec;
use smoothcache::util::json::Json;

fn main() -> smoothcache::util::error::Result<()> {
    let spec = CliSpec::new("stream_cancel", "streaming + cancellation demo")
        .flag("steps", "40", "DDIM steps for the streamed generation")
        .flag("cancel-after", "3", "cancel the second request after this many step events");
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match spec.parse(&argv) {
        Ok(a) => a,
        Err(usage) => {
            eprintln!("{usage}");
            return Ok(());
        }
    };
    let steps = args.usize("steps").map_err(smoothcache::util::error::Error::msg)?;
    let cancel_after = args.usize("cancel-after").map_err(smoothcache::util::error::Error::msg)?;

    let mut cfg = CoordinatorConfig::new(smoothcache::artifacts_dir());
    cfg.preload = vec!["image".into()];
    cfg.max_wait = Duration::from_millis(5);
    let coord = Arc::new(Coordinator::start(cfg)?);
    let server = Server::start("127.0.0.1:0", Arc::clone(&coord), 4)?;
    println!("serving on {}", server.addr);

    // 1. a streamed generation: step events arrive as they execute
    let mut client = Client::connect(&server.addr)?;
    let req = Json::obj()
        .set("family", "image")
        .set("label", 3.0)
        .set("steps", steps)
        .set("policy", "fora:2")
        .set("seed", 7u64);
    println!("\n— streaming a {steps}-step generation —");
    let done = client.call_streaming(&req, |ev| match ev.get("event").and_then(|v| v.as_str()) {
        Some("accepted") => println!("accepted id={}", ev.get("id").unwrap().as_u64().unwrap()),
        Some("step") => println!(
            "  step {:>3}/{} computes={} reuses={} t={:.3}s",
            ev.get("step").and_then(|v| v.as_u64()).unwrap_or(0) + 1,
            ev.get("steps").and_then(|v| v.as_u64()).unwrap_or(0),
            ev.get("computes").and_then(|v| v.as_u64()).unwrap_or(0),
            ev.get("reuses").and_then(|v| v.as_u64()).unwrap_or(0),
            ev.get("t_s").and_then(|v| v.as_f64()).unwrap_or(0.0),
        ),
        _ => {}
    })?;
    println!(
        "done: ok={:?} skip_fraction={:.2}",
        done.get("ok").and_then(|v| v.as_bool()),
        done.get("skip_fraction").and_then(|v| v.as_f64()).unwrap_or(0.0)
    );

    // 2. a long generation cancelled mid-flight from another connection
    println!("\n— cancelling a long generation after {cancel_after} steps —");
    let mut killer = Client::connect(&server.addr)?;
    let long_req = Json::obj()
        .set("family", "image")
        .set("label", 5.0)
        .set("steps", steps * 10)
        .set("policy", "no-cache")
        .set("seed", 8u64);
    let mut id = 0u64;
    let mut seen = 0usize;
    let mut cancelled = false;
    let outcome = client.call_streaming(&long_req, |ev| {
        match ev.get("event").and_then(|v| v.as_str()) {
            Some("accepted") => id = ev.get("id").and_then(|v| v.as_u64()).unwrap_or(0),
            Some("step") => seen += 1,
            _ => {}
        }
        if seen >= cancel_after && !cancelled && id != 0 {
            cancelled = true;
            let acked = killer.cancel(id).expect("cancel rpc");
            println!("  cancel sent from sibling connection (acknowledged: {acked})");
        }
    })?;
    println!(
        "outcome after {seen} step events: ok={:?} cancelled={:?}",
        outcome.get("ok").and_then(|v| v.as_bool()),
        outcome.get("cancelled").and_then(|v| v.as_bool()),
    );

    println!("\ncoordinator metrics: {}", coord.metrics().summary());
    server.stop();
    Ok(())
}
