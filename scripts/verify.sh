#!/usr/bin/env bash
# Tier-1 verification: build, test, and rustdoc with broken intra-doc
# links promoted to errors. Run from anywhere; CI invokes this script.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo doc --no-deps (broken intra-doc links are errors)"
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D rustdoc::broken-intra-doc-links" \
    cargo doc --no-deps --quiet

echo "verify: OK"
