#!/usr/bin/env bash
# Tier-1 verification: build, test (at two GEMM thread counts, so any
# serial/parallel divergence in the compute substrate fails tier-1),
# and rustdoc with broken intra-doc links promoted to errors. Run from
# anywhere; CI invokes this script.
set -euo pipefail
cd "$(dirname "$0")/.."

echo "==> cargo build --release"
cargo build --release

echo "==> cargo fmt --check"
# formatting gate; skipped with a warning when rustfmt is not installed
# (the offline build container has no rustfmt component)
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    echo "warning: rustfmt not installed; skipping format gate" >&2
fi

echo "==> cargo clippy --all-targets -- -D warnings"
# lint gate over every target (lib, bins, tests, benches, examples);
# skipped with a warning when the clippy component is not installed
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    echo "warning: cargo-clippy not installed; skipping lint gate" >&2
fi

echo "==> cargo test -q (SMOOTHCACHE_THREADS=1, serial substrate)"
SMOOTHCACHE_THREADS=1 cargo test -q

echo "==> cargo test -q (SMOOTHCACHE_THREADS=4, parallel substrate)"
SMOOTHCACHE_THREADS=4 cargo test -q

echo "==> cargo doc --no-deps (all rustdoc warnings are errors)"
# -D warnings covers broken intra-doc links, bare URLs, invalid HTML
# tags, …; #![deny(missing_docs)] in coordinator/ and cache/ makes
# undocumented public items fail the build itself.
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" \
    cargo doc --no-deps --quiet

echo "verify: OK"
