#!/usr/bin/env bash
# Tier-1 verification: build, test (at two GEMM thread counts and under
# both kernel dispatches — forced-scalar and auto-SIMD — so any
# serial/parallel or scalar/SIMD divergence in the compute substrate
# fails tier-1; ADR-006 — plus once under SMOOTHCACHE_TRACE=fine so
# instrumentation that perturbs results fails tier-1; ADR-009),
# rustdoc with broken intra-doc links promoted to errors, then the
# smoke-scale bench trajectory gate (docs/benchmarks.md, ADR-005):
# perf_engine and e2e_serving emit BENCH_engine.json / BENCH_serving.json
# plus the mixed-priority preemption lanes (BENCH_serving_mixed_w1/w3,
# docs/adr/007) and the protocol-v2 multiplexing lane
# (BENCH_serving_mux.json, docs/adr/008) at the repo root and
# bench_diff compares them against the committed BENCH_baseline/
# snapshot, failing on out-of-tolerance regressions.
#
# Run from anywhere; CI invokes this script with --strict.
#
# Flags:
#   --strict   optional tools (rustfmt, clippy) and a missing baseline
#              are failures instead of SKIPPED notes — CI mode.
set -euo pipefail
cd "$(dirname "$0")/.."

STRICT=0
for arg in "$@"; do
    case "$arg" in
        --strict) STRICT=1 ;;
        *) echo "usage: $0 [--strict]" >&2; exit 2 ;;
    esac
done

# every stage that cannot run records itself here; the summary at the
# end lists each one explicitly so a pass is never silently partial
SKIPPED=()
skip() {
    if [ "$STRICT" = 1 ]; then
        echo "error (--strict): $1 unavailable — $2" >&2
        exit 1
    fi
    echo "warning: $2; skipping $1" >&2
    SKIPPED+=("$1")
}

echo "==> cargo build --release"
cargo build --release

echo "==> cargo fmt --check"
# formatting gate; the offline build container has no rustfmt component
if cargo fmt --version >/dev/null 2>&1; then
    cargo fmt --all -- --check
else
    skip "cargo-fmt" "rustfmt not installed"
fi

echo "==> cargo clippy --all-targets -- -D warnings"
# lint gate over every target (lib, bins, tests, benches, examples)
if cargo clippy --version >/dev/null 2>&1; then
    cargo clippy --all-targets -- -D warnings
else
    skip "cargo-clippy" "cargo-clippy not installed"
fi

# kernel × thread matrix: lane 1 pins the scalar reference kernel
# (the parity suite's with_kernel scopes outrank the env knob, so the
# scalar-vs-SIMD comparisons still run both kernels here); lane 2 runs
# whatever SIMD microkernel dispatch detects (ADR-006)
echo "==> cargo test -q (SMOOTHCACHE_THREADS=1, SMOOTHCACHE_FORCE_SCALAR=1: serial substrate, scalar kernel)"
SMOOTHCACHE_THREADS=1 SMOOTHCACHE_FORCE_SCALAR=1 cargo test -q

echo "==> cargo test -q (SMOOTHCACHE_THREADS=4, auto kernel: parallel substrate, SIMD when available)"
SMOOTHCACHE_THREADS=4 cargo test -q

# observability lane (docs/adr/009): the whole suite once at the finest
# trace granularity — every parity and golden test passing under
# per-site instrumentation proves tracing never changes results
echo "==> cargo test -q (SMOOTHCACHE_TRACE=fine: full suite under fine-grained tracing)"
SMOOTHCACHE_TRACE=fine cargo test -q

echo "==> cargo doc --no-deps (all rustdoc warnings are errors)"
# -D warnings covers broken intra-doc links, bare URLs, invalid HTML
# tags, …; #![deny(missing_docs)] in coordinator/ and cache/ makes
# undocumented public items fail the build itself.
RUSTDOCFLAGS="${RUSTDOCFLAGS:-} -D warnings" \
    cargo doc --no-deps --quiet

# ---------------------------------------------------------------------------
# bench trajectory gate (smoke scale: 2 steps, image family only)
# ---------------------------------------------------------------------------
echo "==> bench smoke: BENCH_engine.json + BENCH_serving.json"
./target/release/perf_engine --smoke --json BENCH_engine.json
./target/release/e2e_serving --smoke --json BENCH_serving.json

# preemption stress (docs/adr/007): the run-to-completion vs preemptive
# comparison at 1 replica (worst case: every interactive probe lands
# behind a saturating batch-class job) and 3 replicas (thundering-
# preempt shape). Gated rows include priority:interactive/p99_ms, so a
# scheduler regression that starves interactive work fails tier-1.
echo "==> bench smoke: mixed-priority preemption lanes (workers 1, 3)"
./target/release/e2e_serving --smoke --mixed-priority --workers 1 \
    --json BENCH_serving_mixed_w1.json
./target/release/e2e_serving --smoke --mixed-priority --workers 3 \
    --json BENCH_serving_mixed_w3.json

# protocol v2 multiplexing (docs/adr/008): 8 concurrent streams over
# ONE framed connection vs the same work serially over v1 JSON-lines.
# The gated mux_speedup_x row is how a mux/flow-control regression that
# re-serializes concurrent streams fails tier-1.
echo "==> bench smoke: protocol v2 multiplexing lane (8 streams, workers 2)"
./target/release/e2e_serving --smoke --mux 8 --workers 2 \
    --json BENCH_serving_mux.json

for area in engine serving serving_mixed_w1 serving_mixed_w3 serving_mux; do
    report="BENCH_${area}.json"
    baseline="BENCH_baseline/${report}"
    if [ -f "$baseline" ]; then
        echo "==> bench_diff ${baseline} ${report}"
        ./target/release/bench_diff "$baseline" "$report"
    else
        # no baseline yet (fresh checkout / fresh machine): seed it from
        # this run so subsequent runs are gated. Committing the seeded
        # JSON is what arms the gate in CI — deliberately not a --strict
        # failure, since a baseline can only come from an actual run
        # (see docs/benchmarks.md for the refresh workflow).
        mkdir -p BENCH_baseline
        cp "$report" "$baseline"
        echo "seeded ${baseline} from this run — future runs diff against it"
        SKIPPED+=("bench-gate:${area} (baseline seeded)")
    fi
done

# explicit skip summary: a green run says exactly what it did not check
if [ "${#SKIPPED[@]}" -gt 0 ]; then
    for tool in ${SKIPPED[@]+"${SKIPPED[@]}"}; do
        echo "SKIPPED: $tool"
    done
fi
echo "verify: OK"
