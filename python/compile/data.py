"""Synthetic training/calibration corpora (build-time only).

The paper's datasets (ImageNet-1k, VBench prompts, AudioCaps) are
unavailable offline; DESIGN.md section 3 documents the substitutions.
The image corpus below is a 10-class structured Gaussian-blob "latent"
distribution: class identity determines blob position and ring radius,
so a briefly-trained DiT produces visibly class-conditional samples and
Frechet-style metrics respond to generation corruption.
"""

from __future__ import annotations

import numpy as np

from .families import IMAGE, FamilyConfig


def blob_image_batch(rng: np.random.Generator, batch: int,
                     cfg: FamilyConfig = IMAGE):
    """Sample (x0 [B,16,16,4] in ~[-1,1], labels [B] int32)."""
    h, w, _c = cfg.latent_shape
    labels = rng.integers(0, cfg.num_classes, size=batch).astype(np.int32)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float32)
    xs = np.zeros((batch, h, w, 4), np.float32)
    for b in range(batch):
        k = labels[b]
        ang = 2.0 * np.pi * k / cfg.num_classes
        cx = w / 2 + 5.0 * np.cos(ang) + rng.normal(0, 0.4)
        cy = h / 2 + 5.0 * np.sin(ang) + rng.normal(0, 0.4)
        amp = rng.uniform(0.8, 1.2)
        r2 = (xx - cx) ** 2 + (yy - cy) ** 2
        blob = amp * np.exp(-r2 / (2 * 1.5 ** 2))
        ring_r = 2.0 + 0.4 * k
        ring = amp * np.exp(-((np.sqrt(r2) - ring_r) ** 2) / (2 * 0.8 ** 2))
        xs[b, :, :, 0] = 2 * blob - 1
        xs[b, :, :, 1] = (xx - cx) / w * blob * 4
        xs[b, :, :, 2] = (yy - cy) / h * blob * 4
        xs[b, :, :, 3] = 2 * ring - 1
    return xs, labels


def prompt_ids_batch(rng: np.random.Generator, batch: int,
                     cond_len: int, vocab: int):
    """Random non-null prompt token ids (id 0 is the CFG null token)."""
    return rng.integers(1, vocab, size=(batch, cond_len)).astype(np.int32)


def _prompt_param(ids: np.ndarray, slot: int, vocab: int,
                  lo: float, hi: float) -> np.ndarray:
    """Deterministic prompt→parameter mapping: token id in `slot` selects
    a value in [lo, hi]. This is what makes cross-attention *matter*: the
    prompt controls the data the model must generate."""
    return lo + (hi - lo) * ids[:, slot].astype(np.float64) / vocab


def audio_batch(rng: np.random.Generator, batch: int,
                cond_len: int = 8, vocab: int = 256):
    """Prompt-conditioned harmonic audio latents.

    x0: [B, 64, 8]; each channel c carries harmonic (c+1) of a decaying
    tone whose fundamental frequency and decay rate are determined by
    the prompt (matches rust experiments::audio_corpus).
    Returns (x0, prompt_ids).
    """
    t, c = 64, 8
    ids = prompt_ids_batch(rng, batch, cond_len, vocab)
    f0 = _prompt_param(ids, 0, vocab, 0.05, 0.4)
    decay = _prompt_param(ids, 1, vocab, 0.01, 0.05)
    phase = rng.uniform(0, 2 * np.pi, size=batch)
    ti = np.arange(t, dtype=np.float64)
    xs = np.zeros((batch, t, c), np.float64)
    for ci in range(c):
        harm = ci + 1
        xs[:, :, ci] = (np.exp(-ti[None, :] * decay[:, None])
                        * np.sin(f0[:, None] * harm * ti[None, :] * 2 * np.pi
                                 + phase[:, None]) / np.sqrt(harm))
    return xs.astype(np.float32), ids


def video_batch(rng: np.random.Generator, batch: int,
                cond_len: int = 8, vocab: int = 256):
    """Prompt-conditioned moving-blob video latents.

    x0: [B, 4, 8, 8, 4]; a gaussian blob translates across frames with a
    prompt-controlled start position and velocity (matches rust
    experiments::video_corpus). Returns (x0, prompt_ids).
    """
    f, h, w, c = 4, 8, 8, 4
    ids = prompt_ids_batch(rng, batch, cond_len, vocab)
    x0p = _prompt_param(ids, 0, vocab, 1.0, 6.0)
    y0p = _prompt_param(ids, 1, vocab, 1.0, 6.0)
    vx = _prompt_param(ids, 2, vocab, -1.0, 1.0)
    vy = _prompt_param(ids, 3, vocab, -1.0, 1.0)
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    xs = np.zeros((batch, f, h, w, c), np.float64)
    for fi in range(f):
        cx = x0p + vx * fi + rng.normal(0, 0.1, size=batch)
        cy = y0p + vy * fi + rng.normal(0, 0.1, size=batch)
        r2 = ((xx[None] - cx[:, None, None]) ** 2
              + (yy[None] - cy[:, None, None]) ** 2)
        blob = np.exp(-r2 / 3.0)
        for ci in range(c):
            xs[:, fi, :, :, ci] = blob * (1.0 + ci * 0.2) - 0.5
    return xs.astype(np.float32), ids
