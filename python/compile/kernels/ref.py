"""Pure-jnp oracles for every Pallas kernel in this package.

These are the CORE correctness signal: every kernel in attention.py /
mlp.py / modulation.py must match its oracle here to tight tolerances
(pytest + hypothesis sweeps in python/tests/test_kernels.py).

The oracles are also the implementation used when AOT-exporting with
SMOOTHCACHE_IMPL=jnp (see aot.py) which gives the kernel-impl ablation
bench a reference artifact set.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def layernorm(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """LayerNorm over the trailing axis, no learned affine (adaLN style)."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


def ln_modulate(x: jnp.ndarray, shift: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    """adaLN modulation: (1 + scale) * LN(x) + shift.

    x: [B, S, D]; shift/scale: [B, D] broadcast over the sequence axis.
    """
    return layernorm(x, eps) * (1.0 + scale[:, None, :]) + shift[:, None, :]


def gate(y: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """adaLN-zero gating: y * g, g broadcast over the sequence axis.

    y: [B, S, D]; g: [B, D].
    """
    return y * g[:, None, :]


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Scaled dot-product attention over per-head tensors.

    q: [BH, Sq, dh]; k, v: [BH, Sk, dh] -> [BH, Sq, dh].
    Softmax is computed in f32 regardless of the input dtype (this is the
    numerically-stable contract the Pallas kernel also honours).
    """
    dh = q.shape[-1]
    scores = jnp.einsum(
        "bqd,bkd->bqk", q, k, preferred_element_type=jnp.float32
    ) * (1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32)))
    scores = scores - jnp.max(scores, axis=-1, keepdims=True)
    p = jnp.exp(scores)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    out = jnp.einsum("bqk,bkd->bqd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(q.dtype)


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (the variant the Pallas kernel fuses)."""
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, jnp.float32)).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def mlp(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
        w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Fused GELU MLP: gelu(x @ w1 + b1) @ w2 + b2.

    x: [B, S, D]; w1: [D, F]; w2: [F, D].
    """
    h = gelu(jnp.einsum("bsd,df->bsf", x, w1,
                        preferred_element_type=jnp.float32).astype(x.dtype)
             + b1)
    return (jnp.einsum("bsf,fd->bsd", h, w2,
                       preferred_element_type=jnp.float32).astype(x.dtype)
            + b2)
