"""Pallas fused scaled-dot-product attention (self- and cross-).

Hardware adaptation (paper GPU -> TPU-shaped Pallas, DESIGN.md section 4):
the CUDA flash-attention threadblock decomposition becomes a Pallas grid
over fused (batch * heads) with the per-head Q/K/V tiles staged HBM->VMEM
through ``BlockSpec``. At the sequence lengths this repo serves
(S <= 256, dh <= 64) one (S, dh) tile per head fits comfortably inside
the ~16 MiB VMEM budget, so each grid cell computes a full softmax row
block in VMEM with f32 accumulation targeted at the MXU
(``preferred_element_type=jnp.float32``). For longer sequences the
``kv_block`` parameter tiles the K/V axis (online-softmax rescaling),
which is the direct analogue of flash-attention's KV loop.

Kernels MUST be lowered with ``interpret=True``: the CPU PJRT plugin
cannot execute Mosaic custom-calls (see /opt/xla-example/README.md).
VMEM footprint / MXU utilisation estimates for real TPU are recorded in
DESIGN.md section 8 and EXPERIMENTS.md section Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls.


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_block: int):
    """One grid cell = one (batch*head): full Sq rows against tiled Sk."""
    q = q_ref[0].astype(jnp.float32)            # [Sq, dh]
    sq, dh = q.shape
    sk = k_ref.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    nblk = pl.cdiv(sk, kv_block)

    def body(i, carry):
        m_prev, l_prev, acc = carry
        start = i * kv_block
        # dynamic_slice clamps the start so the slice stays in bounds; on
        # the (possibly short) final block the real start is sk - kv_block.
        # Mask rows already covered by earlier blocks so nothing is
        # counted twice.
        real_start = jnp.minimum(start, sk - kv_block)
        k = jax.lax.dynamic_slice_in_dim(
            k_ref[0], real_start, kv_block, axis=0).astype(jnp.float32)
        v = jax.lax.dynamic_slice_in_dim(
            v_ref[0], real_start, kv_block, axis=0).astype(jnp.float32)
        idx = real_start + jax.lax.iota(jnp.int32, kv_block)
        valid = (idx >= start)[None, :]                  # [1, kv_block]
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, -jnp.inf)
        m_cur = jnp.max(s, axis=-1, keepdims=True)       # [Sq, 1]
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                           # [Sq, kv_block]
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + jnp.sum(p, axis=-1, keepdims=True)
        acc = acc * corr + jnp.dot(p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc

    m0 = jnp.full((sq, 1), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((sq, 1), jnp.float32)
    a0 = jnp.zeros((sq, dh), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nblk, body, (m0, l0, a0))
    o_ref[0] = (acc / l).astype(o_ref.dtype)


def attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
              kv_block: int | None = None) -> jnp.ndarray:
    """Fused attention over per-head tensors (one grid cell per head).

    q: [BH, Sq, dh]; k, v: [BH, Sk, dh] -> [BH, Sq, dh].
    Matches ``ref.attention`` bit-for-bit up to f32 accumulation order.
    """
    bh, sq, dh = q.shape
    sk = k.shape[1]
    if kv_block is None:
        kv_block = 128
    kv_block = min(kv_block, sk)
    kernel = functools.partial(_attn_kernel, kv_block=kv_block)
    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, sq, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, sk, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, sk, dh), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, sq, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)


def _attn_kernel_batched(q_ref, k_ref, v_ref, o_ref):
    """One grid cell = one *batch element*, all heads computed together.

    §Perf optimization (EXPERIMENTS.md §Perf L1 iteration 1): the
    per-head grid pays one interpret-mode grid-cell dispatch per
    (batch·head); batching the head axis into the cell cuts dispatches
    by `heads`× while the per-head tiles still map onto MXU-friendly
    batched contractions on real TPU. VMEM per cell grows to
    H·(Sq+2·Sk)·dh floats — still well under the 16 MiB budget at this
    repo's scales (DESIGN.md §8).
    """
    q = q_ref[0].astype(jnp.float32)                 # [H, Sq, dh]
    k = k_ref[0].astype(jnp.float32)                 # [H, Sk, dh]
    v = v_ref[0].astype(jnp.float32)
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    s = jax.lax.dot_general(
        q, k,
        dimension_numbers=(((2,), (2,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    ) * scale                                        # [H, Sq, Sk]
    s = s - jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    o = jax.lax.dot_general(
        p, v,
        dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )                                                # [H, Sq, dh]
    o_ref[0] = o.astype(o_ref.dtype)


def attention_batched(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray) -> jnp.ndarray:
    """Fused attention with the head axis batched inside the grid cell.

    q: [B, H, Sq, dh]; k, v: [B, H, Sk, dh] -> [B, H, Sq, dh].
    Full-softmax variant (K/V resident in VMEM): correct for the
    sequence lengths this repo serves; fall back to [`attention`]'s
    kv_block loop for longer sequences.
    """
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    return pl.pallas_call(
        _attn_kernel_batched,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, h, sq, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, sk, dh), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, h, sk, dh), lambda i: (i, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, sq, dh), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, sq, dh), q.dtype),
        interpret=INTERPRET,
    )(q, k, v)
