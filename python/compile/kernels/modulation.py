"""Pallas fused adaLN modulation + gating epilogues.

Two small VPU-bound kernels that each fuse what would otherwise be 2-3
separate HBM passes:

* ``ln_modulate``: LayerNorm (no affine) fused with the adaLN
  scale/shift: ``(1 + scale) * LN(x) + shift``.
* ``gate``: the adaLN-zero gated pre-residual epilogue ``y * g``.

Grid is over the batch axis; each cell owns the full [S, D] token tile
(VMEM-resident at this repo's sizes). shift/scale/gate are [B, D]
conditioning vectors broadcast over the sequence axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls.


def _ln_modulate_kernel(x_ref, shift_ref, scale_ref, o_ref, *, eps):
    x = x_ref[0].astype(jnp.float32)                  # [S, D]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    n = (x - mu) * jax.lax.rsqrt(var + eps)
    shift = shift_ref[0].astype(jnp.float32)          # [D]
    scale = scale_ref[0].astype(jnp.float32)
    o_ref[0] = (n * (1.0 + scale)[None, :] + shift[None, :]).astype(
        o_ref.dtype)


def ln_modulate(x: jnp.ndarray, shift: jnp.ndarray, scale: jnp.ndarray,
                eps: float = 1e-6) -> jnp.ndarray:
    """x: [B, S, D]; shift/scale: [B, D] -> [B, S, D]."""
    b, s, d = x.shape
    import functools
    kernel = functools.partial(_ln_modulate_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=INTERPRET,
    )(x, shift, scale)


def _gate_kernel(y_ref, g_ref, o_ref):
    o_ref[0] = (y_ref[0] * g_ref[0][None, :]).astype(o_ref.dtype)


def gate(y: jnp.ndarray, g: jnp.ndarray) -> jnp.ndarray:
    """adaLN-zero gating. y: [B, S, D]; g: [B, D] -> [B, S, D]."""
    b, s, d = y.shape
    return pl.pallas_call(
        _gate_kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), y.dtype),
        interpret=INTERPRET,
    )(y, g)
