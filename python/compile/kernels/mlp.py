"""Pallas fused GELU MLP: gelu(x @ w1 + b1) @ w2 + b2.

Hardware adaptation: the CUDA "two GEMMs + fused epilogue" becomes a
Pallas grid over (batch, seq-tiles); each grid cell streams an
(seq_block, D) activation tile through VMEM, runs both MXU contractions
back-to-back and keeps the (seq_block, F) hidden slab entirely in VMEM —
no HBM round-trip for the hidden activations. Weights use constant
index maps (one HBM->VMEM stage, reused across the whole grid row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls.


def _gelu_f32(x):
    c = jnp.sqrt(jnp.asarray(2.0 / jnp.pi, jnp.float32))
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x ** 3)))


def _mlp_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[0]                                     # [sb, D]
    h32 = jnp.dot(x, w1_ref[...],
                  preferred_element_type=jnp.float32)
    h = _gelu_f32(h32.astype(x.dtype).astype(jnp.float32) + b1_ref[...])
    h = h.astype(x.dtype)
    o32 = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[0] = (o32.astype(x.dtype) + b2_ref[...]).astype(o_ref.dtype)


def mlp(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
        w2: jnp.ndarray, b2: jnp.ndarray,
        seq_block: int | None = None) -> jnp.ndarray:
    """Fused GELU MLP. x: [B, S, D]; w1: [D, F]; w2: [F, D] -> [B, S, D]."""
    b, s, d = x.shape
    f = w1.shape[1]
    if seq_block is None:
        seq_block = min(s, 128)
    assert s % seq_block == 0, "seq must divide seq_block"
    kernel = functools.partial(_mlp_kernel)
    return pl.pallas_call(
        kernel,
        grid=(b, s // seq_block),
        in_specs=[
            pl.BlockSpec((1, seq_block, d), lambda i, j: (i, j, 0)),
            pl.BlockSpec((d, f), lambda i, j: (0, 0)),
            pl.BlockSpec((f,), lambda i, j: (0,)),
            pl.BlockSpec((f, d), lambda i, j: (0, 0)),
            pl.BlockSpec((d,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, seq_block, d), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, d), x.dtype),
        interpret=INTERPRET,
    )(x, w1, b1, w2, b2)
