"""weights.bin writer — the Python half of the Rust weight-loading contract.

Format (little-endian):
    8 bytes   magic  b"SMCWGT01"
    4 bytes   u32    header length H
    H bytes   JSON   {"tensors": [{"name", "shape", "offset", "count"}]}
    ...       raw    f32 data; ``offset``/``count`` are in f32 elements
              relative to the start of the data section.

The Rust parser lives in rust/src/model/weights.rs and must round-trip
this exactly (tested on real artifacts in rust/tests/).
"""

from __future__ import annotations

import json
import struct
from typing import Dict

import numpy as np

MAGIC = b"SMCWGT01"


def write_weights(path: str, weights: Dict[str, np.ndarray]) -> None:
    names = sorted(weights)
    tensors = []
    offset = 0
    for n in names:
        a = np.ascontiguousarray(weights[n], dtype=np.float32)
        tensors.append({"name": n, "shape": list(a.shape),
                        "offset": offset, "count": int(a.size)})
        offset += int(a.size)
    header = json.dumps({"tensors": tensors}).encode()
    with open(path, "wb") as f:
        f.write(MAGIC)
        f.write(struct.pack("<I", len(header)))
        f.write(header)
        for n in names:
            f.write(np.ascontiguousarray(
                weights[n], dtype=np.float32).tobytes())


def read_weights(path: str) -> Dict[str, np.ndarray]:
    """Reader (used by tests to verify the round-trip)."""
    with open(path, "rb") as f:
        magic = f.read(8)
        assert magic == MAGIC, f"bad magic {magic!r}"
        (hlen,) = struct.unpack("<I", f.read(4))
        header = json.loads(f.read(hlen))
        data = np.frombuffer(f.read(), dtype="<f4")
    out = {}
    for t in header["tensors"]:
        a = data[t["offset"]:t["offset"] + t["count"]]
        out[t["name"]] = a.reshape(t["shape"]).copy()
    return out
