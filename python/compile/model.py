"""Layer-2: the three DiT families as branch-decomposed JAX functions.

The decomposition mirrors SmoothCache's caching granularity: every
cacheable *branch* (self-attention / cross-attention / feed-forward,
each preceding a residual connection) is an independent function over an
explicit weight list. aot.py lowers each branch once per
(family, branch-type, batch-size); the Rust engine composes the full
forward pass ``x <- x + branch(x, c, W_block)`` and can substitute any
branch execution with a cached output — exactly the paper's mechanism
(Fig. 3: the cached output re-enters through the residual connection).

Implementation selection: ``ops("pallas")`` routes the hot-spots through
the Pallas kernels (the production artifact set), ``ops("jnp")`` through
the pure-jnp oracles (used for goldens, training, and the kernel-impl
ablation). Both paths produce identical numerics (pytest enforces this).
"""

from __future__ import annotations

import functools
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from . import families as fam
from .families import PATCH, FamilyConfig
from .kernels import attention as k_attn
from .kernels import mlp as k_mlp
from .kernels import modulation as k_mod
from .kernels import ref as k_ref


def _attn_variant() -> str:
    """Pallas attention variant: 'batched' (default; heads batched per
    grid cell — §Perf L1 iteration 1) or 'percell' (one head per cell)."""
    import os
    return os.environ.get("SMOOTHCACHE_ATTN", "batched")


def _pallas_attention_4d(q, k, v):
    """Attention over [B, H, S, dh] tensors via the selected kernel."""
    b, h, sq, dh = q.shape
    if _attn_variant() == "batched":
        return k_attn.attention_batched(q, k, v)
    sk = k.shape[2]
    o = k_attn.attention(q.reshape(b * h, sq, dh),
                         k.reshape(b * h, sk, dh),
                         v.reshape(b * h, sk, dh))
    return o.reshape(b, h, sq, dh)


def _ref_attention_4d(q, k, v):
    b, h, sq, dh = q.shape
    sk = k.shape[2]
    o = k_ref.attention(q.reshape(b * h, sq, dh),
                        k.reshape(b * h, sk, dh),
                        v.reshape(b * h, sk, dh))
    return o.reshape(b, h, sq, dh)


class _PallasOps:
    ln_modulate = staticmethod(k_mod.ln_modulate)
    gate = staticmethod(k_mod.gate)
    attention = staticmethod(_pallas_attention_4d)
    mlp = staticmethod(k_mlp.mlp)


class _JnpOps:
    ln_modulate = staticmethod(k_ref.ln_modulate)
    gate = staticmethod(k_ref.gate)
    attention = staticmethod(_ref_attention_4d)
    mlp = staticmethod(k_ref.mlp)


def ops(impl: str):
    if impl == "pallas":
        return _PallasOps
    if impl == "jnp":
        return _JnpOps
    raise ValueError(f"unknown impl {impl!r}")


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def silu(x):
    return x * jax.nn.sigmoid(x)


def timestep_embedding(t: jnp.ndarray, freq_dim: int) -> jnp.ndarray:
    """Sinusoidal embedding of continuous t (scaled to [0, 1000])."""
    half = freq_dim // 2
    freqs = jnp.exp(-np.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    args = (t * 1000.0)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)


def mod_params(c: jnp.ndarray, mod_w: jnp.ndarray, mod_b: jnp.ndarray,
               n: int):
    """adaLN parameters: silu(c) @ mod_w + mod_b, split into n chunks."""
    p = silu(c) @ mod_w + mod_b
    return jnp.split(p, n, axis=-1)


def _split_heads(x: jnp.ndarray, heads: int) -> jnp.ndarray:
    """[B, S, D] -> [B, H, S, dh]."""
    b, s, d = x.shape
    dh = d // heads
    return x.reshape(b, s, heads, dh).transpose(0, 2, 1, 3)


def _merge_heads(x: jnp.ndarray) -> jnp.ndarray:
    """[B, H, S, dh] -> [B, S, D]."""
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


# ---------------------------------------------------------------------------
# Branch bodies (pre-residual, gated): the cacheable units
# ---------------------------------------------------------------------------

def branch_attn(op, cfg: FamilyConfig, x, c,
                mod_w, mod_b, qkv_w, qkv_b, o_w, o_b):
    """Self-attention branch delta: gate * Attn(modulate(LN(x)))."""
    shift, scale, g = mod_params(c, mod_w, mod_b, 3)
    h = op.ln_modulate(x, shift, scale)
    qkv = h @ qkv_w + qkv_b                      # [B, S, 3D]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    o = op.attention(_split_heads(q, cfg.heads),
                     _split_heads(k, cfg.heads),
                     _split_heads(v, cfg.heads))
    y = _merge_heads(o) @ o_w + o_b
    return op.gate(y, g)


def branch_xattn(op, cfg: FamilyConfig, x, cond, c,
                 mod_w, mod_b, q_w, q_b, kv_w, kv_b, o_w, o_b):
    """Cross-attention branch delta over conditioning tokens."""
    shift, scale, g = mod_params(c, mod_w, mod_b, 3)
    h = op.ln_modulate(x, shift, scale)
    q = h @ q_w + q_b                            # [B, S, D]
    kv = cond @ kv_w + kv_b                      # [B, Sc, 2D]
    k, v = jnp.split(kv, 2, axis=-1)
    o = op.attention(_split_heads(q, cfg.heads),
                     _split_heads(k, cfg.heads),
                     _split_heads(v, cfg.heads))
    y = _merge_heads(o) @ o_w + o_b
    return op.gate(y, g)


def branch_ffn(op, cfg: FamilyConfig, x, c,
               mod_w, mod_b, w1, b1, w2, b2):
    """Feed-forward branch delta: gate * MLP(modulate(LN(x)))."""
    shift, scale, g = mod_params(c, mod_w, mod_b, 3)
    h = op.ln_modulate(x, shift, scale)
    y = op.mlp(h, w1, b1, w2, b2)
    return op.gate(y, g)


# --- video factorisation wrappers ------------------------------------------
# tokens are stored flat [B, F*Ssp, D]; spatial branches attend within a
# frame, temporal branches attend across frames at a fixed spatial site.

def _to_spatial(cfg, x):
    b = x.shape[0]
    return x.reshape(b * cfg.frames, cfg.spatial_tokens, cfg.hidden)


def _from_spatial(cfg, x, b):
    return x.reshape(b, cfg.frames * cfg.spatial_tokens, cfg.hidden)


def _to_temporal(cfg, x):
    b = x.shape[0]
    x = x.reshape(b, cfg.frames, cfg.spatial_tokens, cfg.hidden)
    x = x.transpose(0, 2, 1, 3)                  # [B, Ssp, F, D]
    return x.reshape(b * cfg.spatial_tokens, cfg.frames, cfg.hidden)


def _from_temporal(cfg, x, b):
    x = x.reshape(b, cfg.spatial_tokens, cfg.frames, cfg.hidden)
    return x.transpose(0, 2, 1, 3).reshape(
        b, cfg.frames * cfg.spatial_tokens, cfg.hidden)


def _rep(v, times):
    """Repeat conditioning rows for the factorised sub-batch."""
    return jnp.repeat(v, times, axis=0)


def video_branch(op, cfg: FamilyConfig, kind: str, x, cond, c, *w):
    b = x.shape[0]
    if kind.startswith("s_"):
        xs = _to_spatial(cfg, x)
        cs = _rep(c, cfg.frames)
        conds = _rep(cond, cfg.frames) if cond is not None else None
        back = functools.partial(_from_spatial, cfg, b=b)
    else:
        xs = _to_temporal(cfg, x)
        cs = _rep(c, cfg.spatial_tokens)
        conds = _rep(cond, cfg.spatial_tokens) if cond is not None else None
        back = functools.partial(_from_temporal, cfg, b=b)
    base = kind[2:]
    if base == "attn":
        d = branch_attn(op, cfg, xs, cs, *w)
    elif base == "xattn":
        d = branch_xattn(op, cfg, xs, conds, cs, *w)
    else:
        d = branch_ffn(op, cfg, xs, cs, *w)
    return back(d)


def branch_fn(op, cfg: FamilyConfig, branch: str, x, cond, c, *w):
    """Uniform dispatch used by both aot.py and the reference forward."""
    if cfg.name == "video":
        return video_branch(op, cfg, branch, x, cond, c, *w)
    if branch == "attn":
        return branch_attn(op, cfg, x, c, *w)
    if branch == "xattn":
        return branch_xattn(op, cfg, x, cond, c, *w)
    if branch == "ffn":
        return branch_ffn(op, cfg, x, c, *w)
    raise ValueError(f"unknown branch {branch!r} for family {cfg.name}")


# ---------------------------------------------------------------------------
# Embed / final
# ---------------------------------------------------------------------------

def embed(cfg: FamilyConfig, x, t, label, prompt_ids, *w):
    """Patchify + positional + conditioning embeddings.

    Returns (tokens [B,S,D], c [B,D], cond [B,Sc,D] or None).
    label: int32 [B] (image) — num_classes is the learned null row (CFG).
    prompt_ids: int32 [B, Sc] (audio/video) — id 0 is the null token.
    """
    names = fam.embed_weight_names(cfg)
    p = dict(zip(names, w))
    b = x.shape[0]

    if cfg.name == "image":
        h_, w_, ch = cfg.latent_shape
        gh, gw = h_ // PATCH, w_ // PATCH
        xp = x.reshape(b, gh, PATCH, gw, PATCH, ch)
        xp = xp.transpose(0, 1, 3, 2, 4, 5).reshape(
            b, gh * gw, PATCH * PATCH * ch)
    elif cfg.name == "audio":
        xp = x                                    # [B, T, C] already tokens
    else:  # video
        f, h_, w_, ch = cfg.latent_shape
        gh, gw = h_ // PATCH, w_ // PATCH
        xp = x.reshape(b, f, gh, PATCH, gw, PATCH, ch)
        xp = xp.transpose(0, 1, 2, 4, 3, 5, 6).reshape(
            b, f * gh * gw, PATCH * PATCH * ch)

    tokens = xp @ p["patch_w"] + p["patch_b"] + p["pos"][None]

    temb = timestep_embedding(t, cfg.t_freq_dim)
    c = silu(temb @ p["temb_w1"] + p["temb_b1"]) @ p["temb_w2"] + p["temb_b2"]

    cond = None
    if cfg.vocab:
        cond = p["prompt_emb"][prompt_ids]        # [B, Sc, D]
        c = c + jnp.mean(cond, axis=1)
    if cfg.num_classes:
        c = c + p["label_emb"][label]
    return tokens, c, cond


def final(cfg: FamilyConfig, x, c, mod_w, mod_b, lin_w, lin_b):
    """Final adaLN + linear head back to latent shape (epsilon prediction)."""
    shift, scale = mod_params(c, mod_w, mod_b, 2)
    h = k_ref.ln_modulate(x, shift, scale)
    y = h @ lin_w + lin_b                         # [B, S, patch_dim]
    b = x.shape[0]
    if cfg.name == "image":
        h_, w_, ch = cfg.latent_shape
        gh, gw = h_ // PATCH, w_ // PATCH
        y = y.reshape(b, gh, gw, PATCH, PATCH, ch)
        y = y.transpose(0, 1, 3, 2, 4, 5).reshape(b, h_, w_, ch)
    elif cfg.name == "audio":
        pass                                      # [B, T, C] already latent
    else:
        f, h_, w_, ch = cfg.latent_shape
        gh, gw = h_ // PATCH, w_ // PATCH
        y = y.reshape(b, f, gh, gw, PATCH, PATCH, ch)
        y = y.transpose(0, 1, 2, 4, 3, 5, 6).reshape(b, f, h_, w_, ch)
    return y


def patch_dim(cfg: FamilyConfig) -> int:
    if cfg.name == "image":
        return PATCH * PATCH * cfg.latent_shape[2]
    if cfg.name == "audio":
        return cfg.latent_shape[1]
    return PATCH * PATCH * cfg.latent_shape[3]


# ---------------------------------------------------------------------------
# Weight init + full reference forward (training / goldens)
# ---------------------------------------------------------------------------

def init_weights(cfg: FamilyConfig, seed: int,
                 adaln_zero: bool = False) -> Dict[str, np.ndarray]:
    """Deterministic weights, flat dict keyed the way weights_io stores them.

    adaln_zero=True zero-inits the modulation/final linears (DiT's
    adaLN-zero recipe — used for the trained image family); False uses a
    small random init so untrained families still produce non-degenerate
    branch outputs for calibration (DESIGN.md section 3).
    """
    rng = np.random.default_rng(seed)
    d, dff = cfg.hidden, cfg.d_ff

    def lin(shape, std=0.02):
        return rng.standard_normal(shape).astype(np.float32) * std

    def zeros(shape):
        return np.zeros(shape, np.float32)

    w: Dict[str, np.ndarray] = {}
    pd = patch_dim(cfg)
    w["embed.patch_w"] = lin((pd, d))
    w["embed.patch_b"] = zeros((d,))
    w["embed.pos"] = _sincos_pos(cfg).astype(np.float32)
    w["embed.temb_w1"] = lin((cfg.t_freq_dim, d))
    w["embed.temb_b1"] = zeros((d,))
    w["embed.temb_w2"] = lin((d, d))
    w["embed.temb_b2"] = zeros((d,))
    if cfg.num_classes:
        w["embed.label_emb"] = lin((cfg.num_classes + 1, d), std=0.5)
    if cfg.vocab:
        w["embed.prompt_emb"] = lin((cfg.vocab, d), std=0.5)

    mod_std = 0.0 if adaln_zero else 0.02
    for i in range(cfg.depth):
        for br in cfg.branch_types:
            pre = f"blocks.{i}.{br}."
            w[pre + "mod_w"] = (zeros((d, 3 * d)) if adaln_zero
                                else lin((d, 3 * d), mod_std))
            mod_b = zeros((3 * d,))
            if not adaln_zero:
                # unit gate bias: untrained families behave like standard
                # pre-LN transformers (O(1) branch contributions), so
                # caching perturbations are material — trained models have
                # O(1) learned gates too (DESIGN.md §3)
                mod_b[2 * d:] = 1.0
            w[pre + "mod_b"] = mod_b
            if br.endswith("xattn"):
                w[pre + "q_w"] = lin((d, d))
                w[pre + "q_b"] = zeros((d,))
                w[pre + "kv_w"] = lin((d, 2 * d))
                w[pre + "kv_b"] = zeros((2 * d,))
                w[pre + "o_w"] = lin((d, d))
                w[pre + "o_b"] = zeros((d,))
            elif br.endswith("attn"):
                w[pre + "qkv_w"] = lin((d, 3 * d))
                w[pre + "qkv_b"] = zeros((3 * d,))
                w[pre + "o_w"] = lin((d, d))
                w[pre + "o_b"] = zeros((d,))
            else:
                w[pre + "w1"] = lin((d, dff))
                w[pre + "b1"] = zeros((dff,))
                w[pre + "w2"] = lin((dff, d))
                w[pre + "b2"] = zeros((d,))
    w["final.mod_w"] = (zeros((d, 2 * d)) if adaln_zero
                        else lin((d, 2 * d), mod_std))
    w["final.mod_b"] = zeros((2 * d,))
    w["final.lin_w"] = zeros((d, pd)) if adaln_zero else lin((d, pd))
    w["final.lin_b"] = zeros((pd,))
    return w


def _sincos_pos(cfg: FamilyConfig) -> np.ndarray:
    """Fixed sin-cos positional embedding over the flat token axis."""
    s, d = cfg.seq_len, cfg.hidden
    pos = np.arange(s, dtype=np.float32)[:, None]
    div = np.exp(-np.log(10000.0) * np.arange(d // 2, dtype=np.float32)
                 / (d // 2))
    ang = pos * div[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=-1)


def branch_weights(weights: Dict[str, np.ndarray], cfg: FamilyConfig,
                   block: int, branch: str) -> List[np.ndarray]:
    pre = f"blocks.{block}.{branch}."
    return [weights[pre + n] for n in fam.branch_weight_names(cfg, branch)]


def forward(cfg: FamilyConfig, weights: Dict[str, np.ndarray], x, t,
            label=None, prompt_ids=None, impl: str = "jnp",
            collect_deltas: bool = False):
    """Full reference forward pass: embed -> blocks -> final.

    This is the composition the Rust engine must reproduce on golden
    vectors (to <= 1e-4 rel Linf). Returns eps prediction, optionally the
    per-(block, branch) delta list in execution order.
    """
    op = ops(impl)
    ew = [weights["embed." + n] for n in fam.embed_weight_names(cfg)]
    tokens, c, cond = embed(cfg, x, t, label, prompt_ids, *ew)
    deltas = []
    for i in range(cfg.depth):
        for br in cfg.branch_types:
            bw = branch_weights(weights, cfg, i, br)
            d = branch_fn(op, cfg, br, tokens, cond, c, *bw)
            if collect_deltas:
                deltas.append((f"blocks.{i}.{br}", d))
            tokens = tokens + d
    fw = [weights["final." + n] for n in fam.final_weight_names(cfg)]
    eps = final(cfg, tokens, c, *fw)
    if collect_deltas:
        return eps, deltas
    return eps
