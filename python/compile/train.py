"""Build-time trainers for all three families (DESIGN.md section 3).

Real (if tiny) generative-model training runs on the synthetic corpora,
so every served model has *trained* weights: error curves are paper-like
(strong t-dependence, non-degenerate nonlinearity) and quality metrics
respond to caching corruption the way the paper's do.

* image — DDPM epsilon-prediction on the blob corpus (DDIM serving)
* audio — DDPM epsilon-prediction on prompt-conditioned harmonic tones
          (DPM-Solver++ serving)
* video — rectified-flow velocity matching on prompt-conditioned
          moving-blob clips (RF-Euler serving)

All use Adam, classifier-free-guidance dropout (10% null conditioning),
and run once inside ``make artifacts`` (deterministic; seeded).
"""

from __future__ import annotations

import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from . import data
from .families import FamilyConfig, family
from .model import forward, init_weights

T_TRAIN = 1000


def linear_alpha_bar(t: jnp.ndarray) -> jnp.ndarray:
    """alpha_bar(t) for the linear beta schedule, continuous t in [0,1]."""
    steps = jnp.arange(T_TRAIN, dtype=jnp.float32)
    betas = 1e-4 + (0.02 - 1e-4) * steps / (T_TRAIN - 1)
    log_ab = jnp.cumsum(jnp.log1p(-betas))
    idx = jnp.clip((t * (T_TRAIN - 1)).astype(jnp.int32), 0, T_TRAIN - 1)
    return jnp.exp(log_ab[idx])


def _bcast(v, x):
    """Broadcast a [B] vector over the trailing dims of x."""
    return v.reshape((-1,) + (1,) * (x.ndim - 1))


def _sample_batch(cfg: FamilyConfig, rng: np.random.Generator, batch: int):
    """(x0, label, prompt_ids) for one family, with CFG dropout."""
    if cfg.name == "image":
        x0, labels = data.blob_image_batch(rng, batch, cfg)
        drop = rng.random(batch) < 0.1
        labels = np.where(drop, cfg.num_classes, labels).astype(np.int32)
        return x0, labels, None
    if cfg.name == "audio":
        x0, ids = data.audio_batch(rng, batch, cfg.cond_len, cfg.vocab)
    else:
        x0, ids = data.video_batch(rng, batch, cfg.cond_len, cfg.vocab)
    drop = rng.random(batch) < 0.1
    ids = np.where(drop[:, None], 0, ids).astype(np.int32)
    return x0, None, ids


def train_family_weights(family_name: str, steps: int = 300, batch: int = 32,
                         seed: int = 0, lr: float = 2e-3,
                         log_every: int = 50, log=print):
    """Train one family; returns (weights dict, loss history)."""
    cfg = family(family_name)
    w0 = init_weights(cfg, seed=seed, adaln_zero=True)
    names = sorted(w0)
    params = {n: jnp.asarray(w0[n]) for n in names}
    velocity = cfg.name == "video"  # RF flow-matching objective

    def loss_fn(params, x0, labels, prompt_ids, t, eps):
        if velocity:
            # linear path x_t = (1-t)·x0 + t·eps, target v = eps − x0
            xt = _bcast(1.0 - t, x0) * x0 + _bcast(t, x0) * eps
            target = eps - x0
        else:
            ab = linear_alpha_bar(t)
            xt = _bcast(jnp.sqrt(ab), x0) * x0 + _bcast(jnp.sqrt(1 - ab), eps) * eps
            target = eps
        pred = forward(cfg, params, xt, t, labels, prompt_ids, impl="jnp")
        return jnp.mean((pred - target) ** 2)

    @jax.jit
    def step_fn(params, opt_m, opt_v, i, x0, labels, prompt_ids, t, eps):
        loss, grads = jax.value_and_grad(loss_fn)(
            params, x0, labels, prompt_ids, t, eps)
        b1, b2, epsn = 0.9, 0.999, 1e-8
        new_p, new_m, new_v = {}, {}, {}
        bc1 = 1 - b1 ** (i + 1)
        bc2 = 1 - b2 ** (i + 1)
        for n in params:
            g = grads[n]
            m = b1 * opt_m[n] + (1 - b1) * g
            v = b2 * opt_v[n] + (1 - b2) * g * g
            new_m[n], new_v[n] = m, v
            new_p[n] = params[n] - lr * (m / bc1) / (jnp.sqrt(v / bc2) + epsn)
        return new_p, new_m, new_v, loss

    opt_m = {n: jnp.zeros_like(params[n]) for n in names}
    opt_v = {n: jnp.zeros_like(params[n]) for n in names}
    rng = np.random.default_rng(seed + 1)
    t0 = time.time()
    losses = []
    for i in range(steps):
        x0, labels, pids = _sample_batch(cfg, rng, batch)
        t = rng.random(batch).astype(np.float32)
        eps = rng.standard_normal(x0.shape).astype(np.float32)
        params, opt_m, opt_v, loss = step_fn(
            params, opt_m, opt_v, i,
            jnp.asarray(x0),
            None if labels is None else jnp.asarray(labels),
            None if pids is None else jnp.asarray(pids),
            jnp.asarray(t), jnp.asarray(eps))
        losses.append(float(loss))
        if (i + 1) % log_every == 0 or i == 0:
            log(f"  train[{family_name}] step {i+1}/{steps} "
                f"loss={float(loss):.4f} ({time.time()-t0:.1f}s)")
    log(f"  train[{family_name}] done: loss {losses[0]:.4f} -> "
        f"{np.mean(losses[-20:]):.4f} in {time.time()-t0:.1f}s")
    return {n: np.asarray(params[n]) for n in names}, losses


def train_image_weights(steps: int = 300, batch: int = 32, seed: int = 0,
                        lr: float = 2e-3, log_every: int = 50, log=print):
    """Backwards-compatible wrapper (image family)."""
    return train_family_weights("image", steps, batch, seed, lr,
                                log_every, log)
