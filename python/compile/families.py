"""Model-family geometry shared between the JAX build path and Rust.

Three DiT families stand in for the paper's three candidate models
(DESIGN.md section 3 explains each substitution):

* ``image``  — DiT-XL/2 256x256 proxy: adaLN-zero DiT, class-conditional.
* ``audio``  — Stable Audio Open proxy: 1-D latent DiT with
               self-attention, cross-attention and feed-forward branches.
* ``video``  — OpenSora v1.2 STDiT proxy: factorised spatial/temporal
               blocks with 6 cacheable branch types.

Everything Rust needs (dims, branch types, arg orders) is emitted into
``artifacts/manifest.json`` by aot.py; this module is the single source
of truth.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

# Batch sizes we AOT-compile executables for. The Rust dynamic batcher
# pads every batch up to the nearest supported size (vLLM-style bucketing).
SUPPORTED_BATCH_SIZES = (1, 2, 4, 8)


@dataclasses.dataclass(frozen=True)
class FamilyConfig:
    name: str
    hidden: int                 # token width D
    heads: int
    mlp_ratio: int
    depth: int                  # number of DiT blocks (block *pairs* for video)
    latent_shape: Tuple[int, ...]   # per-sample latent tensor shape
    seq_len: int                # flattened token count S
    branch_types: Tuple[str, ...]   # cacheable branch types, in block order
    cond_len: int               # cross-attention conditioning tokens (0 = none)
    num_classes: int            # label classes (image family; 0 = none)
    vocab: int                  # prompt-token vocabulary (0 = none)
    t_freq_dim: int = 64        # sinusoidal timestep embedding width
    # video-only factorisation
    frames: int = 0
    spatial_tokens: int = 0

    @property
    def d_ff(self) -> int:
        return self.hidden * self.mlp_ratio

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    @property
    def latent_size(self) -> int:
        n = 1
        for d in self.latent_shape:
            n *= d
        return n


IMAGE = FamilyConfig(
    name="image",
    hidden=128, heads=4, mlp_ratio=4, depth=6,
    latent_shape=(16, 16, 4),      # H, W, C — patch size 2 -> 8*8 = 64 tokens
    seq_len=64,
    branch_types=("attn", "ffn"),
    cond_len=0, num_classes=10, vocab=0,
)

AUDIO = FamilyConfig(
    name="audio",
    hidden=128, heads=4, mlp_ratio=4, depth=6,
    latent_shape=(64, 8),          # T latent frames x C channels
    seq_len=64,
    branch_types=("attn", "xattn", "ffn"),
    cond_len=8, num_classes=0, vocab=256,
)

VIDEO = FamilyConfig(
    name="video",
    hidden=128, heads=4, mlp_ratio=4, depth=4,
    latent_shape=(4, 8, 8, 4),     # F, H, W, C — patch 2 -> 16 tokens/frame
    seq_len=64,                    # 4 frames * 16 spatial tokens
    branch_types=("s_attn", "s_xattn", "s_ffn",
                  "t_attn", "t_xattn", "t_ffn"),
    cond_len=8, num_classes=0, vocab=256,
    frames=4, spatial_tokens=16,
)

FAMILIES = {f.name: f for f in (IMAGE, AUDIO, VIDEO)}

PATCH = 2  # patchify stride for image / video spatial dims


def family(name: str) -> FamilyConfig:
    return FAMILIES[name]


def branch_weight_names(cfg: FamilyConfig, branch: str) -> List[str]:
    """Per-block weight parameter names for a branch type, in arg order."""
    if branch.endswith("xattn"):
        return ["mod_w", "mod_b", "q_w", "q_b", "kv_w", "kv_b", "o_w", "o_b"]
    if branch.endswith("attn"):
        return ["mod_w", "mod_b", "qkv_w", "qkv_b", "o_w", "o_b"]
    if branch.endswith("ffn"):
        return ["mod_w", "mod_b", "w1", "b1", "w2", "b2"]
    raise ValueError(f"unknown branch type {branch!r}")


def embed_weight_names(cfg: FamilyConfig) -> List[str]:
    names = ["patch_w", "patch_b", "pos",
             "temb_w1", "temb_b1", "temb_w2", "temb_b2"]
    if cfg.num_classes:
        names.append("label_emb")
    if cfg.vocab:
        names.append("prompt_emb")
    return names


def final_weight_names(cfg: FamilyConfig) -> List[str]:
    return ["mod_w", "mod_b", "lin_w", "lin_b"]
