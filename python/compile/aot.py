"""AOT exporter: lower every (family, entry, batch) to HLO text.

This is the single compile-path entrypoint (``make artifacts``). Python
never runs on the request path: everything the Rust binary needs lands
in ``artifacts/``:

    {family}_{entry}_b{B}.hlo.txt   one XLA program per entry per batch
    weights_{family}.bin            flat f32 tensors (weights_io format)
    manifest.json                   geometry + per-entry arg contracts
    goldens/{family}.json           golden vectors pinning the Rust engine

Interchange is HLO **text**, not serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import families as fam
from . import model
from .families import SUPPORTED_BATCH_SIZES, FamilyConfig
from .weights_io import write_weights


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _wspecs(weights, names, prefix=""):
    return [_spec(weights[prefix + n].shape) for n in names]


# ---------------------------------------------------------------------------
# Entry definitions: (runtime inputs, weight names, callable)
# ---------------------------------------------------------------------------

def entries_for(cfg: FamilyConfig, weights, impl: str):
    """Yield (entry_name, fn, input_specs_fn, input_names, weight_names)."""
    op = model.ops(impl)
    d, s = cfg.hidden, cfg.seq_len

    # --- embed ---
    ew_names = fam.embed_weight_names(cfg)

    if cfg.name == "image":
        def embed_fn(x, t, label, *w):
            tokens, c, _ = model.embed(cfg, x, t, label, None, *w)
            return tokens, c
        embed_inputs = ["x", "t", "label"]

        def embed_specs(b):
            return [_spec((b,) + cfg.latent_shape), _spec((b,)),
                    _spec((b,), jnp.int32)]
    else:
        def embed_fn(x, t, prompt_ids, *w):
            tokens, c, cond = model.embed(cfg, x, t, None, prompt_ids, *w)
            return tokens, c, cond
        embed_inputs = ["x", "t", "prompt_ids"]

        def embed_specs(b):
            return [_spec((b,) + cfg.latent_shape), _spec((b,)),
                    _spec((b, cfg.cond_len), jnp.int32)]

    yield ("embed", embed_fn, embed_specs, embed_inputs,
           ["embed." + n for n in ew_names])

    # --- branches ---
    for br in cfg.branch_types:
        wn = fam.branch_weight_names(cfg, br)
        needs_cond = br.endswith("xattn")

        def mk(br=br, needs_cond=needs_cond):
            if needs_cond:
                def branch(x, cond, c, *w):
                    return (model.branch_fn(op, cfg, br, x, cond, c, *w),)
                inputs = ["x", "cond", "c"]

                def specs(b):
                    return [_spec((b, s, d)), _spec((b, cfg.cond_len, d)),
                            _spec((b, d))]
            else:
                def branch(x, c, *w):
                    return (model.branch_fn(op, cfg, br, x, None, c, *w),)
                inputs = ["x", "c"]

                def specs(b):
                    return [_spec((b, s, d)), _spec((b, d))]
            return branch, specs, inputs

        branch, specs, inputs = mk()
        # weight names are templates: Rust substitutes the block index.
        yield (f"branch.{br}", branch, specs, inputs,
               ["blocks.{i}." + br + "." + n for n in wn])

    # --- final ---
    fw_names = fam.final_weight_names(cfg)

    def final_fn(x, c, *w):
        return (model.final(cfg, x, c, *w),)

    def final_specs(b):
        return [_spec((b, s, d)), _spec((b, d))]

    yield ("final", final_fn, final_specs, ["x", "c"],
           ["final." + n for n in fw_names])


def lower_entry(cfg, weights, entry_name, fn, specs_fn, weight_names, batch):
    in_specs = specs_fn(batch)
    w_keys = [n.format(i=0) for n in weight_names]
    w_specs = [_spec(weights[k].shape) for k in w_keys]
    lowered = jax.jit(fn).lower(*(in_specs + w_specs))
    return to_hlo_text(lowered)


# ---------------------------------------------------------------------------
# Goldens
# ---------------------------------------------------------------------------

def make_goldens(cfg: FamilyConfig, weights, seed: int = 123):
    """Golden vectors for the Rust engine (jnp reference path, batch 1)."""
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1,) + cfg.latent_shape).astype(np.float32)
    t = np.array([0.5], np.float32)
    label = np.array([3], np.int32) if cfg.num_classes else None
    pids = (rng.integers(1, cfg.vocab, size=(1, cfg.cond_len))
            .astype(np.int32) if cfg.vocab else None)
    params = {n: jnp.asarray(w) for n, w in weights.items()}
    eps, deltas = model.forward(cfg, params, jnp.asarray(x), jnp.asarray(t),
                                label if label is None else jnp.asarray(label),
                                pids if pids is None else jnp.asarray(pids),
                                impl="jnp", collect_deltas=True)
    ew = [params["embed." + n] for n in fam.embed_weight_names(cfg)]
    tokens, c, cond = model.embed(cfg, jnp.asarray(x), jnp.asarray(t),
                                  None if label is None else jnp.asarray(label),
                                  None if pids is None else jnp.asarray(pids),
                                  *ew)
    g = {
        "family": cfg.name,
        "seed": seed,
        "x": np.asarray(x).ravel().tolist(),
        "t": t.tolist(),
        "label": None if label is None else label.tolist(),
        "prompt_ids": None if pids is None else pids.ravel().tolist(),
        "tokens_l1": float(jnp.sum(jnp.abs(tokens))),
        "c_l1": float(jnp.sum(jnp.abs(c))),
        "cond_l1": None if cond is None else float(jnp.sum(jnp.abs(cond))),
        "branch_delta_l1": {name: float(jnp.sum(jnp.abs(dd)))
                            for name, dd in deltas},
        "eps": np.asarray(eps).ravel().tolist(),
    }
    return g


# ---------------------------------------------------------------------------
# Main
# ---------------------------------------------------------------------------

def family_manifest(cfg: FamilyConfig, entries, impl):
    return {
        "hidden": cfg.hidden, "heads": cfg.heads, "depth": cfg.depth,
        "mlp_ratio": cfg.mlp_ratio, "seq_len": cfg.seq_len,
        "latent_shape": list(cfg.latent_shape),
        "branch_types": list(cfg.branch_types),
        "cond_len": cfg.cond_len, "num_classes": cfg.num_classes,
        "vocab": cfg.vocab, "frames": cfg.frames,
        "spatial_tokens": cfg.spatial_tokens, "patch": fam.PATCH,
        "t_freq_dim": cfg.t_freq_dim,
        "weights_file": f"weights_{cfg.name}.bin",
        "impl": impl,
        "entries": entries,
    }


def load_or_make_weights(cfg: FamilyConfig, train_steps: int, log):
    if train_steps > 0:
        from .train import train_family_weights
        # the video family's factorised blocks make fwd+bwd ~2x the image
        # cost; trim its batch to keep `make artifacts` bounded
        batch = 16 if cfg.name == "video" else 32
        log(f"[aot] training {cfg.name} family for {train_steps} steps ...")
        weights, _losses = train_family_weights(
            cfg.name, steps=train_steps, batch=batch, log=log)
        return weights
    return model.init_weights(cfg, seed=hash(cfg.name) % (2 ** 31))


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--impl", default=os.environ.get(
        "SMOOTHCACHE_IMPL", "pallas"), choices=["pallas", "jnp"])
    ap.add_argument("--families", default="image,audio,video")
    ap.add_argument("--batches", default=",".join(
        str(b) for b in SUPPORTED_BATCH_SIZES))
    ap.add_argument("--train-steps", type=int, default=int(os.environ.get(
        "SMOOTHCACHE_TRAIN_STEPS", "300")))
    args = ap.parse_args(argv)

    out = os.path.abspath(args.out)
    os.makedirs(out, exist_ok=True)
    os.makedirs(os.path.join(out, "goldens"), exist_ok=True)
    batches = [int(b) for b in args.batches.split(",")]
    log = lambda *a: print(*a, file=sys.stderr, flush=True)

    manifest = {"version": 1, "impl": args.impl, "batch_sizes": batches,
                "families": {}}
    t_start = time.time()
    for name in args.families.split(","):
        cfg = fam.family(name)
        weights = load_or_make_weights(cfg, args.train_steps, log)
        write_weights(os.path.join(out, f"weights_{name}.bin"), weights)

        entry_manifest = {}
        for (entry, fn, specs_fn, inputs, wnames) in entries_for(
                cfg, weights, args.impl):
            artifacts = {}
            for b in batches:
                text = lower_entry(cfg, weights, entry, fn, specs_fn,
                                   wnames, b)
                fname = f"{name}_{entry.replace('.', '_')}_b{b}.hlo.txt"
                with open(os.path.join(out, fname), "w") as f:
                    f.write(text)
                artifacts[str(b)] = fname
                log(f"[aot] {fname}: {len(text)//1024} KiB "
                    f"({time.time()-t_start:.0f}s)")
            entry_manifest[entry] = {
                "inputs": inputs,
                "weights": wnames,
                "artifacts": artifacts,
            }
        manifest["families"][name] = family_manifest(
            cfg, entry_manifest, args.impl)

        g = make_goldens(cfg, weights)
        with open(os.path.join(out, "goldens", f"{name}.json"), "w") as f:
            json.dump(g, f)
        log(f"[aot] goldens/{name}.json written")

    with open(os.path.join(out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    log(f"[aot] manifest.json written ({time.time()-t_start:.0f}s total)")


if __name__ == "__main__":
    main()
