"""Model-level tests: shapes, impl equivalence, conditioning behaviour."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import families as fam
from compile import model

ALL = ["image", "audio", "video"]


def _inputs(cfg, b, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((b,) + cfg.latent_shape).astype(np.float32)
    t = rng.random(b).astype(np.float32)
    label = (rng.integers(0, cfg.num_classes, b).astype(np.int32)
             if cfg.num_classes else None)
    pids = (rng.integers(1, cfg.vocab, (b, cfg.cond_len)).astype(np.int32)
            if cfg.vocab else None)
    return (jnp.asarray(x), jnp.asarray(t),
            None if label is None else jnp.asarray(label),
            None if pids is None else jnp.asarray(pids))


@pytest.fixture(scope="module")
def weights():
    return {n: {k: jnp.asarray(v) for k, v in
                model.init_weights(fam.family(n), seed=7).items()}
            for n in ALL}


@pytest.mark.parametrize("name", ALL)
@pytest.mark.parametrize("batch", [1, 2, 4])
def test_forward_shape(name, batch, weights):
    cfg = fam.family(name)
    x, t, label, pids = _inputs(cfg, batch)
    eps = model.forward(cfg, weights[name], x, t, label, pids, impl="jnp")
    assert eps.shape == (batch,) + cfg.latent_shape
    assert np.isfinite(np.asarray(eps)).all()


@pytest.mark.parametrize("name", ALL)
def test_pallas_equals_jnp(name, weights):
    cfg = fam.family(name)
    x, t, label, pids = _inputs(cfg, 2, seed=1)
    e1 = model.forward(cfg, weights[name], x, t, label, pids, impl="jnp")
    e2 = model.forward(cfg, weights[name], x, t, label, pids, impl="pallas")
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2),
                               rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("name", ALL)
def test_branch_deltas_order_and_count(name, weights):
    cfg = fam.family(name)
    x, t, label, pids = _inputs(cfg, 1)
    _, deltas = model.forward(cfg, weights[name], x, t, label, pids,
                              impl="jnp", collect_deltas=True)
    assert len(deltas) == cfg.depth * len(cfg.branch_types)
    want = [f"blocks.{i}.{br}" for i in range(cfg.depth)
            for br in cfg.branch_types]
    assert [n for n, _ in deltas] == want
    for _, d in deltas:
        assert d.shape == (1, cfg.seq_len, cfg.hidden)


def test_timestep_embedding_distinguishes_t():
    e1 = model.timestep_embedding(jnp.asarray([0.1]), 64)
    e2 = model.timestep_embedding(jnp.asarray([0.9]), 64)
    assert np.abs(np.asarray(e1) - np.asarray(e2)).max() > 0.1


def test_label_conditioning_changes_output(weights):
    cfg = fam.family("image")
    x, t, _, _ = _inputs(cfg, 1)
    e0 = model.forward(cfg, weights["image"], x, t,
                       jnp.asarray([0], jnp.int32), None)
    e1 = model.forward(cfg, weights["image"], x, t,
                       jnp.asarray([5], jnp.int32), None)
    assert np.abs(np.asarray(e0) - np.asarray(e1)).max() > 1e-5


def test_prompt_conditioning_changes_output(weights):
    cfg = fam.family("audio")
    x, t, _, pids = _inputs(cfg, 1)
    e0 = model.forward(cfg, weights["audio"], x, t, None, pids)
    e1 = model.forward(cfg, weights["audio"], x, t, None,
                       jnp.zeros_like(pids))
    assert np.abs(np.asarray(e0) - np.asarray(e1)).max() > 1e-5


def test_adaln_zero_init_gives_input_independent_eps():
    """With adaLN-zero init every branch delta is zero -> eps is the
    (zero-init) final head output: exactly zero."""
    cfg = fam.family("image")
    w = {k: jnp.asarray(v) for k, v in
         model.init_weights(cfg, seed=0, adaln_zero=True).items()}
    x, t, label, _ = _inputs(cfg, 1)
    eps, deltas = model.forward(cfg, w, x, t, label, None,
                                collect_deltas=True)
    for _, d in deltas:
        assert np.abs(np.asarray(d)).max() == 0.0
    assert np.abs(np.asarray(eps)).max() == 0.0


def test_video_spatial_temporal_round_trip():
    cfg = fam.family("video")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(
        (2, cfg.seq_len, cfg.hidden)).astype(np.float32))
    from compile.model import (_from_spatial, _from_temporal, _to_spatial,
                               _to_temporal)
    np.testing.assert_array_equal(
        np.asarray(_from_spatial(cfg, _to_spatial(cfg, x), 2)),
        np.asarray(x))
    np.testing.assert_array_equal(
        np.asarray(_from_temporal(cfg, _to_temporal(cfg, x), 2)),
        np.asarray(x))


def test_cross_timestep_similarity_exists():
    """The paper's core observation (section 2.1): branch outputs at nearby
    t are similar. Verify the relative L1 error between adjacent-t branch
    outputs on the SAME x_t is small vs distant-t."""
    cfg = fam.family("image")
    w = {k: jnp.asarray(v) for k, v in
         model.init_weights(cfg, seed=7).items()}
    x, _, label, _ = _inputs(cfg, 1)

    def deltas_at(tv):
        _, ds = model.forward(cfg, w, x, jnp.asarray([tv], jnp.float32),
                              label, None, collect_deltas=True)
        return ds

    d0 = deltas_at(0.50)
    d_near = deltas_at(0.52)
    d_far = deltas_at(0.95)

    def rel(a, b):
        a, b = np.asarray(a), np.asarray(b)
        return np.abs(a - b).sum() / (np.abs(a).sum() + 1e-12)

    near = np.mean([rel(a[1], b[1]) for a, b in zip(d0, d_near)])
    far = np.mean([rel(a[1], b[1]) for a, b in zip(d0, d_far)])
    assert near < far
