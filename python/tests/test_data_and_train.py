"""Synthetic corpus and trainer tests (build-path correctness)."""

import numpy as np
import pytest

from compile import data
from compile.families import IMAGE
from compile.train import linear_alpha_bar, train_image_weights


def test_blob_batch_shapes_and_range():
    rng = np.random.default_rng(0)
    xs, labels = data.blob_image_batch(rng, 16)
    assert xs.shape == (16, 16, 16, 4)
    assert labels.shape == (16,)
    assert labels.min() >= 0 and labels.max() < IMAGE.num_classes
    assert np.abs(xs).max() < 3.0  # roughly normalized


def test_blob_batch_class_structure():
    """Same-class samples are closer than different-class samples."""
    rng = np.random.default_rng(1)
    xs, labels = data.blob_image_batch(rng, 64)
    same, diff = [], []
    for i in range(32):
        for j in range(i + 1, 32):
            d = np.linalg.norm(xs[i] - xs[j])
            (same if labels[i] == labels[j] else diff).append(d)
    if same and diff:
        assert np.mean(same) < np.mean(diff)


def test_prompt_ids_exclude_null():
    rng = np.random.default_rng(2)
    ids = data.prompt_ids_batch(rng, 8, 8, 256)
    assert ids.shape == (8, 8)
    assert ids.min() >= 1  # id 0 reserved for the CFG null token


def test_linear_alpha_bar_monotone():
    import jax.numpy as jnp
    ts = jnp.linspace(0.0, 1.0, 50)
    ab = np.asarray(linear_alpha_bar(ts))
    assert ab[0] > 0.99
    assert ab[-1] < 0.01
    assert (np.diff(ab) <= 1e-9).all()


@pytest.mark.slow
def test_training_reduces_loss():
    _, losses = train_image_weights(steps=12, batch=16, log_every=100,
                                    log=lambda *a: None)
    assert losses[-1] < losses[0]
