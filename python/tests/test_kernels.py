"""Kernel-vs-oracle correctness: the core L1 signal.

Hypothesis sweeps shapes/dtypes for every Pallas kernel and asserts
allclose against the pure-jnp oracles in kernels/ref.py.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as k_attn
from compile.kernels import mlp as k_mlp
from compile.kernels import modulation as k_mod
from compile.kernels import ref

SETTINGS = dict(max_examples=12, deadline=None)


def _rand(rng, shape, dtype, scale=1.0):
    a = rng.standard_normal(shape).astype(np.float32) * scale
    return jnp.asarray(a, dtype=dtype)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=2e-5, atol=2e-5)


def _close(a, b, dtype):
    np.testing.assert_allclose(
        np.asarray(a, np.float32), np.asarray(b, np.float32), **_tol(dtype))


@settings(**SETTINGS)
@given(bh=st.sampled_from([1, 2, 8]),
       sq=st.sampled_from([1, 16, 64]),
       sk=st.sampled_from([8, 48, 64, 100]),
       dh=st.sampled_from([8, 32, 64]),
       kv_block=st.sampled_from([8, 16, 128]),
       seed=st.integers(0, 2 ** 16))
def test_attention_matches_ref(bh, sq, sk, dh, kv_block, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (bh, sq, dh), jnp.float32)
    k = _rand(rng, (bh, sk, dh), jnp.float32)
    v = _rand(rng, (bh, sk, dh), jnp.float32)
    got = k_attn.attention(q, k, v, kv_block=kv_block)
    want = ref.attention(q, k, v)
    _close(got, want, jnp.float32)


@settings(**SETTINGS)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 2 ** 16))
def test_attention_dtypes(dtype, seed):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (4, 32, 16), dtype)
    k = _rand(rng, (4, 32, 16), dtype)
    v = _rand(rng, (4, 32, 16), dtype)
    got = k_attn.attention(q, k, v)
    assert got.dtype == dtype
    _close(got, ref.attention(q, k, v), dtype)


def test_attention_large_magnitude_stable():
    """Online-softmax rescaling must survive large score magnitudes."""
    rng = np.random.default_rng(0)
    q = _rand(rng, (2, 16, 8), jnp.float32, scale=30.0)
    k = _rand(rng, (2, 16, 8), jnp.float32, scale=30.0)
    v = _rand(rng, (2, 16, 8), jnp.float32)
    got = k_attn.attention(q, k, v, kv_block=4)
    assert np.isfinite(np.asarray(got)).all()
    _close(got, ref.attention(q, k, v), jnp.float32)


def test_attention_softmax_rows_are_convex_combos():
    """Output rows lie inside the convex hull of V rows (softmax weights)."""
    rng = np.random.default_rng(1)
    q = _rand(rng, (1, 8, 4), jnp.float32)
    k = _rand(rng, (1, 8, 4), jnp.float32)
    v = _rand(rng, (1, 8, 4), jnp.float32)
    out = np.asarray(k_attn.attention(q, k, v))
    vmin = np.asarray(v).min(axis=1, keepdims=True)
    vmax = np.asarray(v).max(axis=1, keepdims=True)
    assert (out >= vmin - 1e-5).all() and (out <= vmax + 1e-5).all()


@settings(**SETTINGS)
@given(b=st.sampled_from([1, 2, 4]),
       s=st.sampled_from([32, 64, 128]),
       d=st.sampled_from([32, 128]),
       f=st.sampled_from([64, 256]),
       seq_block=st.sampled_from([16, 32]),
       seed=st.integers(0, 2 ** 16))
def test_mlp_matches_ref(b, s, d, f, seq_block, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, s, d), jnp.float32)
    w1 = _rand(rng, (d, f), jnp.float32, 0.05)
    b1 = _rand(rng, (f,), jnp.float32, 0.05)
    w2 = _rand(rng, (f, d), jnp.float32, 0.05)
    b2 = _rand(rng, (d,), jnp.float32, 0.05)
    got = k_mlp.mlp(x, w1, b1, w2, b2, seq_block=seq_block)
    _close(got, ref.mlp(x, w1, b1, w2, b2), jnp.float32)


def test_mlp_rejects_indivisible_seq_block():
    rng = np.random.default_rng(0)
    x = _rand(rng, (1, 60, 16), jnp.float32)
    w1 = _rand(rng, (16, 32), jnp.float32)
    b1 = _rand(rng, (32,), jnp.float32)
    w2 = _rand(rng, (32, 16), jnp.float32)
    b2 = _rand(rng, (16,), jnp.float32)
    with pytest.raises(AssertionError):
        k_mlp.mlp(x, w1, b1, w2, b2, seq_block=32)


@settings(**SETTINGS)
@given(b=st.sampled_from([1, 3, 8]),
       s=st.sampled_from([16, 64]),
       d=st.sampled_from([32, 128, 256]),
       seed=st.integers(0, 2 ** 16))
def test_ln_modulate_matches_ref(b, s, d, seed):
    rng = np.random.default_rng(seed)
    x = _rand(rng, (b, s, d), jnp.float32, 3.0)
    shift = _rand(rng, (b, d), jnp.float32)
    scale = _rand(rng, (b, d), jnp.float32)
    got = k_mod.ln_modulate(x, shift, scale)
    _close(got, ref.ln_modulate(x, shift, scale), jnp.float32)


def test_ln_modulate_zero_params_is_plain_layernorm():
    rng = np.random.default_rng(2)
    x = _rand(rng, (2, 16, 64), jnp.float32)
    z = jnp.zeros((2, 64), jnp.float32)
    got = k_mod.ln_modulate(x, z, z)
    _close(got, ref.layernorm(x), jnp.float32)
    # normalized rows: mean 0, var 1
    m = np.asarray(got).mean(-1)
    np.testing.assert_allclose(m, np.zeros_like(m), atol=1e-5)


@settings(**SETTINGS)
@given(b=st.sampled_from([1, 2, 8]),
       s=st.sampled_from([8, 64]),
       d=st.sampled_from([16, 128]),
       seed=st.integers(0, 2 ** 16))
def test_gate_matches_ref(b, s, d, seed):
    rng = np.random.default_rng(seed)
    y = _rand(rng, (b, s, d), jnp.float32)
    g = _rand(rng, (b, d), jnp.float32)
    _close(k_mod.gate(y, g), ref.gate(y, g), jnp.float32)


def test_gate_zero_gate_zeroes_branch():
    """adaLN-zero at init: zero gate must kill the branch delta exactly."""
    rng = np.random.default_rng(3)
    y = _rand(rng, (2, 16, 32), jnp.float32)
    g = jnp.zeros((2, 32), jnp.float32)
    assert np.abs(np.asarray(k_mod.gate(y, g))).max() == 0.0


@settings(**SETTINGS)
@given(b=st.sampled_from([1, 2, 4]),
       h=st.sampled_from([1, 4]),
       sq=st.sampled_from([1, 16, 64]),
       sk=st.sampled_from([8, 64]),
       dh=st.sampled_from([8, 32]),
       seed=st.integers(0, 2 ** 16))
def test_attention_batched_matches_ref(b, h, sq, sk, dh, seed):
    """The §Perf 'heads batched per grid cell' kernel variant."""
    rng = np.random.default_rng(seed)
    q = _rand(rng, (b, h, sq, dh), jnp.float32)
    k = _rand(rng, (b, h, sk, dh), jnp.float32)
    v = _rand(rng, (b, h, sk, dh), jnp.float32)
    got = k_attn.attention_batched(q, k, v)
    want = ref.attention(q.reshape(b * h, sq, dh),
                         k.reshape(b * h, sk, dh),
                         v.reshape(b * h, sk, dh)).reshape(b, h, sq, dh)
    _close(got, want, jnp.float32)


def test_attention_variants_agree():
    rng = np.random.default_rng(9)
    q = _rand(rng, (2, 4, 16, 8), jnp.float32)
    k = _rand(rng, (2, 4, 16, 8), jnp.float32)
    v = _rand(rng, (2, 4, 16, 8), jnp.float32)
    a = k_attn.attention_batched(q, k, v)
    b = k_attn.attention(q.reshape(8, 16, 8), k.reshape(8, 16, 8),
                         v.reshape(8, 16, 8)).reshape(2, 4, 16, 8)
    _close(a, b, jnp.float32)
