"""AOT pipeline tests: weights round-trip, manifest integrity, HLO export."""

import json
import os

import numpy as np
import pytest

from compile import aot, families as fam, model
from compile.weights_io import read_weights, write_weights


def test_weights_roundtrip(tmp_path):
    rng = np.random.default_rng(0)
    w = {"a.b": rng.standard_normal((3, 4)).astype(np.float32),
         "scalarish": rng.standard_normal((1,)).astype(np.float32),
         "deep.nested.name": rng.standard_normal((2, 3, 5)).astype(
             np.float32)}
    p = str(tmp_path / "w.bin")
    write_weights(p, w)
    got = read_weights(p)
    assert set(got) == set(w)
    for k in w:
        np.testing.assert_array_equal(got[k], w[k])


def test_weights_file_magic(tmp_path):
    p = str(tmp_path / "w.bin")
    write_weights(p, {"x": np.zeros((2,), np.float32)})
    with open(p, "rb") as f:
        assert f.read(8) == b"SMCWGT01"


@pytest.mark.parametrize("name", ["image", "audio", "video"])
def test_entries_cover_all_branches(name):
    cfg = fam.family(name)
    w = model.init_weights(cfg, seed=0)
    entries = list(aot.entries_for(cfg, w, "jnp"))
    names = [e[0] for e in entries]
    assert names[0] == "embed" and names[-1] == "final"
    assert set(names[1:-1]) == {f"branch.{b}" for b in cfg.branch_types}


@pytest.mark.parametrize("name", ["image", "audio"])
def test_lower_entry_produces_hlo_text(name):
    cfg = fam.family(name)
    w = model.init_weights(cfg, seed=0)
    entries = list(aot.entries_for(cfg, w, "jnp"))
    entry, fn, specs_fn, inputs, wnames = entries[1]  # first branch
    text = aot.lower_entry(cfg, w, entry, fn, specs_fn, wnames, batch=1)
    assert text.startswith("HloModule")
    assert "ROOT" in text


def test_branch_weight_templates_resolve():
    cfg = fam.family("video")
    w = model.init_weights(cfg, seed=0)
    entries = list(aot.entries_for(cfg, w, "jnp"))
    for entry, _, _, _, wnames in entries:
        if not entry.startswith("branch."):
            continue
        for i in range(cfg.depth):
            for tpl in wnames:
                assert tpl.format(i=i) in w, (entry, tpl, i)


def test_goldens_structure():
    cfg = fam.family("audio")
    w = model.init_weights(cfg, seed=0)
    g = aot.make_goldens(cfg, w)
    assert len(g["x"]) == cfg.latent_size
    assert len(g["eps"]) == cfg.latent_size
    assert len(g["branch_delta_l1"]) == cfg.depth * len(cfg.branch_types)
    assert all(v > 0 for v in g["branch_delta_l1"].values())


ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="artifacts not built (run `make artifacts`)")
def test_built_manifest_is_complete():
    with open(os.path.join(ART, "manifest.json")) as f:
        m = json.load(f)
    for name, famm in m["families"].items():
        cfg = fam.family(name)
        assert set(famm["entries"]) == (
            {"embed", "final"} | {f"branch.{b}" for b in cfg.branch_types})
        for entry in famm["entries"].values():
            for b, fname in entry["artifacts"].items():
                path = os.path.join(ART, fname)
                assert os.path.exists(path), fname
                with open(path) as f:
                    assert f.read(9) == "HloModule"
        assert os.path.exists(os.path.join(ART, famm["weights_file"]))
